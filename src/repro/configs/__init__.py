from .registry import ARCH_IDS, get_config, get_reduced, list_archs
from .shapes import LONG_CONTEXT_ARCHS, SHAPES, ShapeSpec, cells_for

__all__ = [
    "ARCH_IDS", "get_config", "get_reduced", "list_archs",
    "LONG_CONTEXT_ARCHS", "SHAPES", "ShapeSpec", "cells_for",
]
