"""qwen2.5-32b [dense] — GQA with QKV bias.

[hf:Qwen/Qwen2.5-32B (scaled from 0.5B card); hf]  64L d_model=5120 40H
(kv=8) d_ff=27648 vocab=152064; RoPE base 1e6; untied embeddings.
"""

from repro.models.common import ArchConfig

CONFIG = ArchConfig(
    arch_id="qwen2.5-32b", family="dense",
    num_layers=64, d_model=5120, num_heads=40, num_kv_heads=8,
    d_ff=27648, vocab_size=152064,
    qkv_bias=True, rope_base=1_000_000.0, tie_embeddings=False,
)

REDUCED = ArchConfig(
    arch_id="qwen2.5-32b-smoke", family="dense",
    num_layers=3, d_model=80, num_heads=5, num_kv_heads=1,
    d_ff=160, vocab_size=256,
    qkv_bias=True, rope_base=1_000_000.0, tie_embeddings=False,
)
