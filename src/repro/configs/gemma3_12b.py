"""gemma3-12b [dense] — 5:1 local:global, 128k context.

[hf:google/gemma-3-12b-pt (scaled from 1b-pt card); unverified]
48L d_model=3840 16H (kv=8, head_dim=256) d_ff=15360 vocab=262144;
window 1024 on 5-of-6 layers; RoPE base 1M (global) / 10k (local);
QK-norm instead of softcap; sandwich norms.
"""

from repro.models.common import ArchConfig

CONFIG = ArchConfig(
    arch_id="gemma3-12b", family="dense",
    num_layers=48, d_model=3840, num_heads=16, num_kv_heads=8, head_dim=256,
    d_ff=15360, vocab_size=262144,
    local_window=1024, pattern_local=5, pattern_global=1,
    rope_base=1_000_000.0, rope_base_local=10_000.0,
    qk_norm=True, query_scale=256 ** -0.5, post_norms=True, embed_scale=True,
    activation="gelu_tanh",
)

REDUCED = ArchConfig(
    arch_id="gemma3-12b-smoke", family="dense",
    num_layers=6, d_model=64, num_heads=4, num_kv_heads=2, head_dim=16,
    d_ff=128, vocab_size=256,
    local_window=8, pattern_local=5, pattern_global=1,
    rope_base=1_000_000.0, rope_base_local=10_000.0,
    qk_norm=True, query_scale=16 ** -0.5, post_norms=True, embed_scale=True,
    activation="gelu_tanh",
)
