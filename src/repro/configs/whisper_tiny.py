"""whisper-tiny [audio] — enc-dec; conv/mel frontend is a stub.

[arXiv:2212.04356; unverified]  4L encoder + 4L decoder, d_model=384,
6H (kv=6, head_dim=64), d_ff=1536, vocab=51865; encoder length 1500;
LayerNorm, plain GELU MLP, learned decoder positions.
"""

from repro.models.common import ArchConfig

CONFIG = ArchConfig(
    arch_id="whisper-tiny", family="audio",
    num_layers=4, d_model=384, num_heads=6, num_kv_heads=6,
    d_ff=1536, vocab_size=51865,
    encoder_layers=4, encoder_len=1500,
    activation="gelu", gated=False, norm_eps=1e-5,
)

REDUCED = ArchConfig(
    arch_id="whisper-tiny-smoke", family="audio",
    num_layers=2, d_model=64, num_heads=4, num_kv_heads=4,
    d_ff=128, vocab_size=256,
    encoder_layers=2, encoder_len=16,
    activation="gelu", gated=False, norm_eps=1e-5,
)
