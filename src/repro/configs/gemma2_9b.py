"""gemma2-9b [dense] — local+global alternating, logit softcaps.

[arXiv:2408.00118; hf:google/gemma-2-9b]  42L d_model=3584 16H (kv=8,
head_dim=256) d_ff=14336 vocab=256000; sliding window 4096 on alternating
layers; attn-logit softcap 50, final-logit softcap 30; sandwich norms;
embeddings scaled by sqrt(d_model); GeGLU.
"""

from repro.models.common import ArchConfig

CONFIG = ArchConfig(
    arch_id="gemma2-9b", family="dense",
    num_layers=42, d_model=3584, num_heads=16, num_kv_heads=8, head_dim=256,
    d_ff=14336, vocab_size=256000,
    local_window=4096, pattern_local=1, pattern_global=1,
    attn_logit_softcap=50.0, final_logit_softcap=30.0,
    query_scale=256 ** -0.5, post_norms=True, embed_scale=True,
    activation="gelu_tanh",
)

REDUCED = ArchConfig(
    arch_id="gemma2-9b-smoke", family="dense",
    num_layers=4, d_model=64, num_heads=4, num_kv_heads=2, head_dim=16,
    d_ff=128, vocab_size=256,
    local_window=8, pattern_local=1, pattern_global=1,
    attn_logit_softcap=50.0, final_logit_softcap=30.0,
    query_scale=16 ** -0.5, post_norms=True, embed_scale=True,
    activation="gelu_tanh",
)
