"""llava-next-34b [vlm] — anyres tiling stub over a 34B LM backbone.

[hf:llava-hf/llava-v1.6-34b (Yi-34B backbone); unverified]
60L d_model=7168 56H (kv=8, head_dim=128) d_ff=20480 vocab=64000;
576 patch embeddings fuse as the sequence prefix (frontend stub).
"""

from repro.models.common import ArchConfig

CONFIG = ArchConfig(
    arch_id="llava-next-34b", family="vlm",
    num_layers=60, d_model=7168, num_heads=56, num_kv_heads=8, head_dim=128,
    d_ff=20480, vocab_size=64000,
    rope_base=5_000_000.0, num_patches=576, tie_embeddings=False,
)

REDUCED = ArchConfig(
    arch_id="llava-next-34b-smoke", family="vlm",
    num_layers=3, d_model=64, num_heads=8, num_kv_heads=2, head_dim=8,
    d_ff=128, vocab_size=256,
    rope_base=5_000_000.0, num_patches=4, tie_embeddings=False,
)
