"""rwkv6-3b [ssm] — Finch: attention-free, data-dependent decay.

[arXiv:2404.05892; hf:RWKV/rwkv-6-world-3b]  32L d_model=2560 d_ff=8960
vocab=65536; head_size 64 (40 WKV heads); O(1) decode state.
"""

from repro.models.common import ArchConfig

CONFIG = ArchConfig(
    arch_id="rwkv6-3b", family="ssm",
    num_layers=32, d_model=2560, num_heads=40, num_kv_heads=40,
    d_ff=8960, vocab_size=65536, rwkv_head_size=64,
)

REDUCED = ArchConfig(
    arch_id="rwkv6-3b-smoke", family="ssm",
    num_layers=3, d_model=64, num_heads=4, num_kv_heads=4,
    d_ff=224, vocab_size=256, rwkv_head_size=16,
)
