"""Architecture registry: ``--arch <id>`` resolution for every launcher."""

from __future__ import annotations

import importlib
from typing import List, Tuple

from repro.models.common import ArchConfig

__all__ = ["ARCH_IDS", "get_config", "get_reduced", "list_archs"]

_MODULES = {
    "gemma2-9b": "gemma2_9b",
    "gemma3-12b": "gemma3_12b",
    "starcoder2-7b": "starcoder2_7b",
    "qwen2.5-32b": "qwen2_5_32b",
    "rwkv6-3b": "rwkv6_3b",
    "whisper-tiny": "whisper_tiny",
    "hymba-1.5b": "hymba_1_5b",
    "qwen3-moe-235b-a22b": "qwen3_moe_235b",
    "llama4-scout-17b-a16e": "llama4_scout",
    "llava-next-34b": "llava_next_34b",
}

ARCH_IDS: Tuple[str, ...] = tuple(_MODULES)


def _load(arch_id: str):
    try:
        mod = _MODULES[arch_id]
    except KeyError:
        raise KeyError(
            f"unknown arch {arch_id!r}; known: {', '.join(ARCH_IDS)}"
        ) from None
    return importlib.import_module(f"repro.configs.{mod}")


def get_config(arch_id: str) -> ArchConfig:
    return _load(arch_id).CONFIG


def get_reduced(arch_id: str) -> ArchConfig:
    return _load(arch_id).REDUCED


def list_archs() -> List[str]:
    return list(ARCH_IDS)
