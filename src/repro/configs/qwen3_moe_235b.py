"""qwen3-moe-235b-a22b [moe] — 128 experts, top-8, QK-norm.

[hf:Qwen/Qwen3-235B-A22B (scaled from Qwen3-30B-A3B card); hf]
94L d_model=4096 64H (kv=4, head_dim=128) expert_d_ff=1536 vocab=151936;
softmax-over-top-k router (norm_topk_prob), no shared expert.
"""

from repro.models.common import ArchConfig

CONFIG = ArchConfig(
    arch_id="qwen3-moe-235b-a22b", family="moe",
    num_layers=94, d_model=4096, num_heads=64, num_kv_heads=4, head_dim=128,
    d_ff=1536, vocab_size=151936,
    num_experts=128, experts_per_token=8, expert_d_ff=1536,
    qk_norm=True, rope_base=1_000_000.0, tie_embeddings=False,
)

REDUCED = ArchConfig(
    arch_id="qwen3-moe-smoke", family="moe",
    num_layers=3, d_model=64, num_heads=4, num_kv_heads=2, head_dim=16,
    d_ff=32, vocab_size=256,
    num_experts=8, experts_per_token=2, expert_d_ff=32,
    qk_norm=True, rope_base=1_000_000.0, tie_embeddings=False,
)
