import os
os.environ["XLA_FLAGS"] = (
    "--xla_force_host_platform_device_count=512 "
    + os.environ.get("XLA_FLAGS", "")
)
# dry-runs simulate the pod on forced *host* devices; without this an
# accelerator-capable install hangs probing for real hardware first
os.environ.setdefault("JAX_PLATFORMS", "cpu")
# ^ MUST precede every other import: JAX locks the device count on first use.

"""Multi-pod dry-run: lower + compile every (arch × shape × mesh) cell.

For each cell this driver builds the model from its full config, creates
ShapeDtypeStruct stand-ins for params/optimizer/batch (zero allocation),
jits the train/prefill/decode step with explicit in/out shardings,
``.lower().compile()``s it for the single-pod (16×16) and multi-pod
(2×16×16) production meshes, and records:

* ``compiled.cost_analysis()``  — HLO FLOPs / bytes (per partition),
* ``compiled.memory_analysis()`` — argument/output/temp bytes per device,
* a collective inventory parsed from the post-SPMD HLO (op type, result
  bytes, group size, ring-adjusted wire bytes),
* the three roofline terms (DESIGN.md §8) against v5e constants.

Results land in ``experiments/dryrun/<arch>__<shape>__<mesh>.json`` and are
aggregated by ``benchmarks/roofline.py`` into EXPERIMENTS.md tables.

Usage::

    python -m repro.launch.dryrun --arch gemma2-9b --shape train_4k
    python -m repro.launch.dryrun --all [--multi-pod] [--skip-existing]
"""

import argparse
import json
import re
import time
import traceback
from pathlib import Path

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import SHAPES, cells_for, get_config, list_archs
from repro.launch.hlo_cost import analyze_hlo
from repro.launch.mesh import make_production_mesh
from repro.launch.steps import make_batch_stub, make_decode_fn, make_prefill_fn, make_train_step
from repro.models import build_model, mesh_context
from repro.models.common import ArchConfig
from repro.optim import adamw_init
from repro.parallel.sharding import (
    batch_shardings,
    decode_state_shardings,
    named,
    opt_state_shardings,
    param_shardings,
)
from jax.sharding import PartitionSpec as P

# ---- v5e roofline constants (per chip) -------------------------------------
PEAK_FLOPS = 197e12          # bf16
HBM_BW = 819e9               # bytes/s
ICI_BW = 50e9                # bytes/s per link

_COLL_RE = re.compile(
    r"(\w+)\[([\d,]*)\][^=]*?\s(all-reduce|all-gather|reduce-scatter"
    r"|all-to-all|collective-permute)(?:-start)?\(",
)
_GROUPS_RE = re.compile(r"replica_groups=(?:\[(\d+),(\d+)\]|(\{\{[^}]*\}))")

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "f16": 2, "bf16": 2, "s64": 8, "u64": 8,
    "s32": 4, "u32": 4, "s16": 2, "u16": 2, "s8": 1, "u8": 1, "pred": 1,
    "c64": 8, "c128": 16,
}


def parse_collectives(hlo_text: str):
    """Collective inventory with ring-adjusted per-device wire bytes."""
    out = []
    for line in hlo_text.splitlines():
        m = _COLL_RE.search(line)
        if not m:
            continue
        dtype, dims, op = m.group(1), m.group(2), m.group(3)
        if dtype not in _DTYPE_BYTES:
            continue
        elems = 1
        if dims:
            for d in dims.split(","):
                if d:
                    elems *= int(d)
        nbytes = elems * _DTYPE_BYTES[dtype]
        g = _GROUPS_RE.search(line)
        group = 1
        if g:
            if g.group(2):                      # iota [num_groups,size]<=[...]
                group = int(g.group(2))
            elif g.group(3):
                group = g.group(3).count(",") + 1
        n = max(group, 2)
        if op == "all-reduce":
            wire = 2 * nbytes * (n - 1) / n
        elif op == "all-gather":
            wire = nbytes * (n - 1) / n         # nbytes = gathered result
        elif op == "reduce-scatter":
            wire = nbytes * (n - 1)             # nbytes = scattered result
        elif op == "all-to-all":
            wire = nbytes * (n - 1) / n
        else:                                    # collective-permute
            wire = nbytes
        out.append({"op": op, "bytes": nbytes, "group": group, "wire": wire})
    return out


def model_flops(cfg: ArchConfig, kind: str, batch: int, seq: int) -> float:
    """Analytic MODEL_FLOPS = 6·N_active·tokens (train) / 2·N·tokens (fwd)."""
    n = cfg.active_param_count()
    if kind == "train":
        return 6.0 * n * batch * seq
    if kind == "prefill":
        return 2.0 * n * batch * seq
    return 2.0 * n * batch  # decode: one token per sequence


def build_cell(arch: str, shape_name: str, *, multi_pod: bool,
               decode_layout: str = "seq", remat: str = "full",
               extra: dict | None = None):
    """Returns (jitted_fn, example_args, meta) ready to lower."""
    cfg = get_config(arch)
    shape = SHAPES[shape_name]
    extra = extra or {}
    mesh = make_production_mesh(multi_pod=multi_pod)
    model = build_model(cfg, impl="xla", remat=remat, decode_layout=decode_layout)

    n_batch_shards = mesh.shape.get("pod", 1) * mesh.shape["data"]
    divisible = shape.global_batch % n_batch_shards == 0

    rng = jax.random.PRNGKey(0)
    p_shapes = jax.eval_shape(model.init, rng)
    param_mode = extra.get("param_mode", "train")
    hd_div = cfg.num_heads % mesh.shape["model"] == 0
    p_shard = param_shardings(p_shapes, mesh, mode=param_mode,
                              heads_divisible=hd_div)

    if shape.kind == "train":
        o_shapes = jax.eval_shape(adamw_init, p_shapes)
        o_shard = opt_state_shardings(o_shapes, mesh,
                                      heads_divisible=hd_div)
        batch = make_batch_stub(cfg, batch=shape.global_batch,
                                seq=shape.seq_len, kind="train")
        b_shard = batch_shardings(batch, mesh, batch_divisible=divisible)
        step = make_train_step(model)
        rep = named(mesh, P())
        m_shard = {k: rep for k in
                   ("ce", "aux", "tokens", "loss", "gnorm", "lr")}
        fn = jax.jit(step, in_shardings=(p_shard, o_shard, b_shard),
                     out_shardings=(p_shard, o_shard, m_shard),
                     donate_argnums=(0, 1))
        args = (p_shapes, o_shapes, batch)
    elif shape.kind == "prefill":
        batch = make_batch_stub(cfg, batch=shape.global_batch,
                                seq=shape.seq_len, kind="prefill")
        b_shard = batch_shardings(batch, mesh, batch_divisible=divisible)
        prefill = make_prefill_fn(model, max_seq=shape.seq_len)
        s_shapes = jax.eval_shape(prefill, p_shapes, batch)[0]
        s_shard = decode_state_shardings(s_shapes, mesh, layout=decode_layout,
                                         batch_divisible=divisible)
        l_shard = named(mesh, P(("pod", "data") if divisible else None, None))
        fn = jax.jit(prefill, in_shardings=(p_shard, b_shard),
                     out_shardings=(s_shard, l_shard))
        args = (p_shapes, batch)
    else:  # decode
        state_shapes = jax.eval_shape(
            lambda: model.init_decode_state(shape.global_batch, shape.seq_len)
        )
        s_shard = decode_state_shardings(state_shapes, mesh,
                                         layout=decode_layout,
                                         batch_divisible=divisible)
        tok = jax.ShapeDtypeStruct((shape.global_batch,), jnp.int32)
        t_shard = named(mesh, P(("pod", "data") if divisible else None))
        l_shard = named(mesh, P(("pod", "data") if divisible else None, None))
        decode = make_decode_fn(model)
        fn = jax.jit(decode, in_shardings=(p_shard, s_shard, t_shard),
                     out_shardings=(s_shard, l_shard), donate_argnums=(1,))
        args = (p_shapes, state_shapes, tok)

    meta = {
        "arch": arch, "shape": shape_name, "kind": shape.kind,
        "mesh": "2x16x16" if multi_pod else "16x16",
        "chips": int(np.prod(list(mesh.shape.values()))),
        "decode_layout": decode_layout, "remat": remat,
        "params": cfg.param_count(), "active_params": cfg.active_param_count(),
        "model_flops": model_flops(cfg, shape.kind, shape.global_batch,
                                   shape.seq_len),
    }
    meta.update(extra)
    return mesh, fn, args, meta


def run_cell(arch: str, shape_name: str, *, multi_pod: bool, out_dir: Path,
             decode_layout: str = "seq", remat: str = "full",
             tag: str = "", extra: dict | None = None) -> dict:
    mesh, fn, args, meta = build_cell(
        arch, shape_name, multi_pod=multi_pod,
        decode_layout=decode_layout, remat=remat, extra=extra,
    )
    chips = meta["chips"]
    with mesh, mesh_context(mesh):
        t0 = time.time()
        lowered = fn.lower(*args)
        t_lower = time.time() - t0
        t0 = time.time()
        compiled = lowered.compile()
        t_compile = time.time() - t0

    # ---- analyses -----------------------------------------------------------
    try:
        from repro.compat import cost_analysis as _ca
        cost = _ca(compiled)
    except Exception as e:  # pragma: no cover
        cost = {"error": str(e)}
    try:
        mem = compiled.memory_analysis()
        mem_d = {
            "argument_bytes": getattr(mem, "argument_size_in_bytes", None),
            "output_bytes": getattr(mem, "output_size_in_bytes", None),
            "temp_bytes": getattr(mem, "temp_size_in_bytes", None),
            "alias_bytes": getattr(mem, "alias_size_in_bytes", None),
            "peak_bytes": getattr(mem, "peak_memory_in_bytes", None),
        }
    except Exception as e:  # pragma: no cover
        mem_d = {"error": str(e)}

    hlo = compiled.as_text()
    # Loop-aware static analysis (XLA's cost_analysis counts while bodies
    # once; analyze_hlo multiplies by trip counts — see hlo_cost.py).
    hc = analyze_hlo(hlo)
    coll_by_op = hc.collectives
    wire_bytes = hc.wire_bytes

    flops = float(hc.flops)
    bytes_accessed = float(hc.bytes)
    compute_s = flops / PEAK_FLOPS
    memory_s = bytes_accessed / HBM_BW
    collective_s = wire_bytes / ICI_BW

    dominant = max(
        ("compute", compute_s), ("memory", memory_s), ("collective", collective_s),
        key=lambda kv: kv[1],
    )[0]
    mf = meta["model_flops"]
    useful_ratio = mf / (flops * chips) if flops else 0.0

    result = {
        **meta,
        "lower_s": round(t_lower, 2),
        "compile_s": round(t_compile, 2),
        "hlo_flops_per_chip": flops,
        "hlo_bytes_per_chip": bytes_accessed,
        "collective_wire_bytes_per_chip": wire_bytes,
        "collectives": coll_by_op,
        "while_trip_counts": hc.while_trip_counts[:8],
        "xla_cost_analysis": {
            "flops_single_visit": float(cost.get("flops", 0.0)),
            "bytes_single_visit": float(cost.get("bytes accessed", 0.0)),
        },
        "memory": mem_d,
        "roofline": {
            "compute_s": compute_s,
            "memory_s": memory_s,
            "collective_s": collective_s,
            "dominant": dominant,
            "step_s_lower_bound": max(compute_s, memory_s, collective_s),
            "useful_flop_ratio": useful_ratio,
        },
        "transcript_lines": hlo.count("\n"),
        "ok": True,
    }
    out_dir.mkdir(parents=True, exist_ok=True)
    name = f"{arch.replace('/', '_')}__{shape_name}__{meta['mesh']}"
    if tag:
        name += f"__{tag}"
    (out_dir / f"{name}.json").write_text(json.dumps(result, indent=1))
    return result


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--both-meshes", action="store_true")
    ap.add_argument("--decode-layout", default="seq", choices=["heads", "seq"])
    ap.add_argument("--remat", default="full", choices=["full", "dots", "none"])
    ap.add_argument("--tag", default="")
    ap.add_argument("--serve-params", action="store_true",
                    help="§Perf-C1: replicate dense weights over data for "
                         "decode/prefill (no per-token FSDP gathers)")
    ap.add_argument("--skip-existing", action="store_true")
    ap.add_argument("--out", default="experiments/dryrun")
    args = ap.parse_args()

    out_dir = Path(args.out)
    cells = []
    if args.all:
        for arch in list_archs():
            for shape in cells_for(arch):
                cells.append((arch, shape))
    else:
        assert args.arch and args.shape, "--arch/--shape or --all"
        if args.shape not in cells_for(args.arch):
            print(f"[n/a]  {args.arch}__{args.shape} (DESIGN.md §4 skip)")
            return
        cells = [(args.arch, args.shape)]

    meshes = [args.multi_pod]
    if args.both_meshes:
        meshes = [False, True]

    failures = 0
    for arch, shape in cells:
        for mp in meshes:
            mesh_name = "2x16x16" if mp else "16x16"
            fname = f"{arch}__{shape}__{mesh_name}"
            if args.tag:
                fname += f"__{args.tag}"
            if args.skip_existing and (out_dir / f"{fname}.json").exists():
                print(f"[skip] {fname}")
                continue
            t0 = time.time()
            try:
                r = run_cell(arch, shape, multi_pod=mp, out_dir=out_dir,
                             decode_layout=args.decode_layout,
                             remat=args.remat, tag=args.tag,
                             extra={"param_mode": "serve"}
                             if args.serve_params else None)
                rf = r["roofline"]
                print(
                    f"[ok]   {fname}  compile={r['compile_s']:.0f}s "
                    f"flops/chip={r['hlo_flops_per_chip']:.3e} "
                    f"dom={rf['dominant']} "
                    f"bound={rf['step_s_lower_bound']*1e3:.2f}ms "
                    f"useful={rf['useful_flop_ratio']:.2f}",
                    flush=True,
                )
            except Exception as e:
                failures += 1
                print(f"[FAIL] {fname}  {type(e).__name__}: {e}", flush=True)
                traceback.print_exc()
                (out_dir / f"{fname}.FAILED.txt").write_text(
                    f"{e}\n{traceback.format_exc()}"
                )
            print(f"       ({time.time()-t0:.0f}s)", flush=True)
            jax.clear_caches()
    if failures:
        raise SystemExit(f"{failures} cells failed")


if __name__ == "__main__":
    main()
