"""Production serving launcher: continuous batching over the paged arena.

Example (CPU, reduced config)::

    PYTHONPATH=src python -m repro.launch.serve \
        --arch qwen2.5-32b --reduced --requests 8 --new-tokens 8
"""

from __future__ import annotations

import argparse
import json
import time

import jax
import numpy as np

from repro.configs import get_config, get_reduced, list_archs
from repro.core.tasks import TenantQuota
from repro.models import build_model
from repro.runtime import Request, Server, ServerConfig


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--arch", default="qwen2.5-32b", choices=list_archs())
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--max-batch", type=int, default=4)
    ap.add_argument("--max-seq", type=int, default=128)
    ap.add_argument("--new-tokens", type=int, default=8)
    ap.add_argument("--legacy-arena", action="store_true",
                    help="A/B: run the KV arena under the paper's buggy "
                         "legacy allocator")
    ap.add_argument("--metrics-port", type=int, default=None, metavar="PORT",
                    help="serve Prometheus text metrics on "
                         "http://127.0.0.1:PORT/metrics (0 = ephemeral)")
    ap.add_argument("--pool-watermark", type=int, default=0,
                    help="keep this many warm postprocess sandboxes via "
                         "the background refiller (0 = off)")
    ap.add_argument("--workers", type=int, default=0,
                    help="run request post-processors on this many "
                         "concurrent scheduler workers (0 = inline)")
    ap.add_argument("--heartbeat-timeout", type=float, default=0.0,
                    metavar="SECONDS",
                    help="reap postprocess workers silent this long "
                         "mid-task; their task requeues exactly once and "
                         "a replacement worker is spawned (0 = off; "
                         "needs --workers > 0)")
    ap.add_argument("--hold", type=float, default=0.0, metavar="SECONDS",
                    help="keep the process (and /metrics) alive after the "
                         "batch completes, e.g. to scrape it")
    ap.add_argument("--tenant", default="serving", metavar="NAMES",
                    help="comma-separated tenant names assigned to the "
                         "requests round-robin (admission identity; "
                         "default one 'serving' tenant)")
    ap.add_argument("--quota", type=int, default=0, metavar="SLOTS",
                    help="cap each tenant at this many concurrent decode "
                         "slots (0 = uncapped)")
    ap.add_argument("--deadline", type=float, default=None, metavar="SECONDS",
                    help="admit deadline per request: a request still "
                         "queued this long after arrival completes with "
                         "an 'expired' error instead of serving")
    ap.add_argument("--no-incremental", action="store_true",
                    help="A/B: run the old rebatching baseline (every "
                         "admit re-prefills the whole batch) instead of "
                         "per-slot incremental prefill")
    ap.add_argument("--kv-mode", default="auto",
                    choices=("auto", "paged", "dense"),
                    help="KV backing store: 'paged' routes decode through "
                         "the Pallas paged-attention kernel over the "
                         "arena's page pool; 'dense' keeps the per-slot "
                         "(batch, max_seq) reservation; 'auto' picks "
                         "paged when the model supports it")
    ap.add_argument("--temperature", type=float, default=0.0,
                    help="sampling temperature (0 = greedy argmax)")
    ap.add_argument("--top-k", type=int, default=0,
                    help="sample from the top K tokens (0 = no cap; "
                         "needs --temperature > 0)")
    ap.add_argument("--top-p", type=float, default=1.0,
                    help="nucleus sampling mass (1.0 = off; needs "
                         "--temperature > 0)")
    ap.add_argument("--seed", type=int, default=0,
                    help="base sampling seed; request i draws with seed "
                         "base+i, so the token streams are reproducible "
                         "run to run (and across chaos evictions)")
    args = ap.parse_args()

    cfg = get_reduced(args.arch) if args.reduced else get_config(args.arch)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    tenants = [t.strip() for t in args.tenant.split(",") if t.strip()] \
        or ["serving"]
    quotas = (
        {t: TenantQuota(max_tasks_in_flight=args.quota) for t in tenants}
        if args.quota > 0 else None
    )
    srv = Server(model, params, ServerConfig(
        max_batch=args.max_batch, max_seq=args.max_seq,
        mm_legacy=args.legacy_arena, pool_watermark=args.pool_watermark,
        workers=args.workers, heartbeat_timeout_s=args.heartbeat_timeout,
        incremental=not args.no_incremental, quotas=quotas,
        kv_mode=args.kv_mode,
    ))
    print(f"[serve] kv_mode: {srv.engine.kv_mode}")
    if args.metrics_port is not None:
        endpoint = srv.serve_metrics(port=args.metrics_port)
        print(f"[serve] metrics: {endpoint.url}")
    rng = np.random.default_rng(0)
    reqs = [
        Request(
            prompt=rng.integers(0, cfg.vocab_size,
                                (int(rng.integers(4, 12)),)).astype(np.int32),
            max_new_tokens=args.new_tokens, request_id=i,
            tenant=tenants[i % len(tenants)], deadline_s=args.deadline,
            temperature=args.temperature, top_k=args.top_k,
            top_p=args.top_p, seed=args.seed + i,
        )
        for i in range(args.requests)
    ]
    done = srv.run(reqs)
    for r in sorted(done, key=lambda r: r.request_id):
        status = f"ERROR: {r.error}" if r.error else (
            f"{len(r.tokens)} tokens "
            f"{r.tokens[:8]}{'...' if len(r.tokens) > 8 else ''}"
        )
        print(f"[serve] req {r.request_id} [{r.tenant}]: {status} "
              f"latency {r.latency_s*1e3:.0f}ms")
    print(f"[serve] arena ({'legacy' if args.legacy_arena else 'modern'}): "
          f"{json.dumps(srv.arena_report()['mm_stats'])}")
    stats = srv.engine.serving_stats()
    print(f"[serve] kv pages: allocated={stats['kv_pages_allocated_total']} "
          f"freed={stats['kv_pages_freed_total']} "
          f"resumed={stats['resumed_total']} "
          f"sampled={json.dumps(stats['sampled_tokens_total'])}")
    if args.metrics_port is not None:
        pool = {k: v for k, v in srv.dump_metrics().items()
                if k.startswith("seepp_pool")}
        print(f"[serve] pool metrics: {json.dumps(pool)}")
        if args.hold > 0:
            print(f"[serve] holding /metrics open for {args.hold:.0f}s ...")
            time.sleep(args.hold)
    srv.close()


if __name__ == "__main__":
    main()
