"""Production serving launcher: continuous batching over the paged arena.

Example (CPU, reduced config)::

    PYTHONPATH=src python -m repro.launch.serve \
        --arch qwen2.5-32b --reduced --requests 8 --new-tokens 8
"""

from __future__ import annotations

import argparse
import json

import jax
import numpy as np

from repro.configs import get_config, get_reduced, list_archs
from repro.models import build_model
from repro.runtime import Request, Server, ServerConfig


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--arch", default="qwen2.5-32b", choices=list_archs())
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--max-batch", type=int, default=4)
    ap.add_argument("--max-seq", type=int, default=128)
    ap.add_argument("--new-tokens", type=int, default=8)
    ap.add_argument("--legacy-arena", action="store_true",
                    help="A/B: run the KV arena under the paper's buggy "
                         "legacy allocator")
    args = ap.parse_args()

    cfg = get_reduced(args.arch) if args.reduced else get_config(args.arch)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    srv = Server(model, params, ServerConfig(
        max_batch=args.max_batch, max_seq=args.max_seq,
        mm_legacy=args.legacy_arena,
    ))
    rng = np.random.default_rng(0)
    reqs = [
        Request(
            prompt=rng.integers(0, cfg.vocab_size,
                                (int(rng.integers(4, 12)),)).astype(np.int32),
            max_new_tokens=args.new_tokens, request_id=i,
        )
        for i in range(args.requests)
    ]
    done = srv.run(reqs)
    for r in sorted(done, key=lambda r: r.request_id):
        print(f"[serve] req {r.request_id}: {len(r.tokens)} tokens "
              f"{r.tokens[:8]}{'...' if len(r.tokens) > 8 else ''} "
              f"latency {r.latency_s*1e3:.0f}ms")
    print(f"[serve] arena ({'legacy' if args.legacy_arena else 'modern'}): "
          f"{json.dumps(srv.arena_report()['mm_stats'])}")


if __name__ == "__main__":
    main()
