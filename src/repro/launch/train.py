"""Production training launcher.

Builds the mesh from available devices (or the production 16×16 via
``--dryrun-devices``), shards params/optimizer/batch per
``repro.parallel.sharding``, and runs the fault-tolerant trainer with
checkpointing and sandboxed data transforms.

Example (CPU, reduced config)::

    PYTHONPATH=src python -m repro.launch.train \
        --arch gemma2-9b --reduced --steps 100 --global-batch 8 --seq 64
"""

from __future__ import annotations

import argparse

import jax

from repro.checkpoint import CheckpointManager
from repro.configs import get_config, get_reduced, list_archs
from repro.core.gofer import Gofer
from repro.data import DataConfig, Loader, SyntheticLM
from repro.launch.mesh import make_host_mesh
from repro.models import build_model, mesh_context
from repro.optim import ScheduleConfig
from repro.runtime import (
    HeartbeatMonitor,
    StragglerDetector,
    Trainer,
    TrainerConfig,
)


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--arch", default="gemma2-9b", choices=list_archs())
    ap.add_argument("--reduced", action="store_true",
                    help="use the smoke-scale config (CPU-friendly)")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--global-batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--accum", type=int, default=1)
    ap.add_argument("--lr", type=float, default=3e-3)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_ckpt")
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--resume", action="store_true")
    args = ap.parse_args()

    cfg = get_reduced(args.arch) if args.reduced else get_config(args.arch)
    model = build_model(cfg)
    mesh = make_host_mesh()
    print(f"[train] arch={cfg.arch_id} params≈{cfg.param_count():,} "
          f"mesh={dict(mesh.shape)}")

    dc = DataConfig(global_batch=args.global_batch, seq_len=args.seq,
                    vocab_size=cfg.vocab_size)
    loader = Loader(SyntheticLM(dc), dc)
    ckpt = CheckpointManager(
        Gofer.for_root("ckpt", args.ckpt_dir, write=True), keep=3)
    trainer = Trainer(
        model, loader,
        TrainerConfig(
            total_steps=args.steps, accum_steps=args.accum,
            ckpt_every=args.ckpt_every, log_every=10,
            schedule=ScheduleConfig(peak_lr=args.lr, warmup_steps=20,
                                    decay_steps=args.steps),
        ),
        ckpt=ckpt,
        monitor=HeartbeatMonitor(["host0"]),
        stragglers=StragglerDetector(),
    )

    with mesh, mesh_context(mesh):
        params, opt = trainer.init_state(jax.random.PRNGKey(0))
        start = 0
        if args.resume:
            restored = ckpt.restore_latest({"params": params, "opt": opt})
            if restored is not None:
                start, tree, _ = restored
                params, opt = tree["params"], tree["opt"]
                print(f"[train] resumed from step {start}")
        params, opt = trainer.run(params, opt, start_step=start)

    loader.stop()
    for row in trainer.metrics_log:
        print(f"[train] step {row['step']:5d} loss {row['loss']:.4f} "
              f"gnorm {row['gnorm']:.3f} lr {row['lr']:.2e} "
              f"({row['secs']:.2f}s)")
    print(f"[train] done; checkpoints: {ckpt.all_steps()}")


if __name__ == "__main__":
    main()
