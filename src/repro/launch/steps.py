"""Step functions shared by the trainer, server and dry-run."""

from __future__ import annotations

from typing import Any, Callable, Dict, Optional

import jax
import jax.numpy as jnp

from repro.optim import AdamWConfig, ScheduleConfig, adamw_update, lr_at

__all__ = ["make_train_step", "make_prefill_fn", "make_decode_fn", "make_batch_stub"]


def make_train_step(
    model,
    schedule: ScheduleConfig = ScheduleConfig(),
    opt_cfg: AdamWConfig = AdamWConfig(),
) -> Callable:
    def train_step(params, opt_state, batch):
        (loss, metrics), grads = jax.value_and_grad(model.loss, has_aux=True)(
            params, batch
        )
        lr = lr_at(opt_state["step"], schedule)
        params, opt_state, gnorm = adamw_update(
            grads, opt_state, params, lr, opt_cfg
        )
        metrics = dict(metrics)
        metrics.update(loss=loss, gnorm=gnorm, lr=lr)
        return params, opt_state, metrics

    return train_step


def make_prefill_fn(model, *, max_seq: Optional[int] = None) -> Callable:
    cfg = model.cfg

    def prefill(params, batch):
        kw = {}
        if cfg.family == "audio":
            kw["frames"] = batch["frames"]
        elif cfg.num_patches:
            kw["patch_embeds"] = batch["patch_embeds"]
        return model.prefill(params, batch["tokens"], max_seq=max_seq, **kw)

    return prefill


def make_decode_fn(model) -> Callable:
    def decode(params, state, tokens):
        return model.decode_step(params, state, tokens)

    return decode


def make_batch_stub(cfg, *, batch: int, seq: int, kind: str) -> Dict[str, Any]:
    """ShapeDtypeStruct stand-ins for every model input (no allocation)."""
    tok = jax.ShapeDtypeStruct((batch, seq), jnp.int32)
    stub: Dict[str, Any] = {"tokens": tok}
    if kind == "train":
        stub["targets"] = tok
        stub["loss_mask"] = jax.ShapeDtypeStruct((batch, seq), jnp.float32)
    if cfg.family == "audio":
        stub["frames"] = jax.ShapeDtypeStruct(
            (batch, cfg.encoder_len, cfg.d_model), jnp.bfloat16
        )
    elif cfg.num_patches:
        stub["patch_embeds"] = jax.ShapeDtypeStruct(
            (batch, cfg.num_patches, cfg.d_model), jnp.bfloat16
        )
    return stub
