"""Static cost analysis over optimized (post-SPMD) HLO text.

``compiled.cost_analysis()`` counts each ``while`` body **once**, so a
scan-over-layers module under-reports FLOPs, bytes and collective traffic
by a factor of the layer count.  This module re-derives the three roofline
terms from the HLO text itself:

* builds the computation graph (fusions, while bodies/conditions,
  conditional branches, calls),
* extracts per-computation costs: dot/convolution FLOPs from shapes and
  contracting dims, collective wire bytes (ring-adjusted by replica-group
  size), and an HBM-traffic approximation (operands + results of
  *top-level* ops in each computation — values inside a fusion stay in
  registers/VMEM and are not charged),
* resolves ``while`` trip counts from the loop condition's comparison
  constant, and aggregates costs bottom-up with trip multiplication.

Validated against ``compiled.cost_analysis()`` on loop-free modules
(tests/test_hlo_cost.py) and against analytic 6·N·D on the dry-run cells.
"""

from __future__ import annotations

import math
import re
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Tuple

__all__ = ["analyze_hlo", "HloCost"]

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "f16": 2, "bf16": 2, "f8e4m3fn": 1, "f8e5m2": 1,
    "s64": 8, "u64": 8, "s32": 4, "u32": 4, "s16": 2, "u16": 2,
    "s8": 1, "u8": 1, "s4": 1, "u4": 1, "pred": 1, "c64": 8, "c128": 16,
    "token": 0, "opaque": 0,
}

_COMP_HEADER = re.compile(r"^(?:ENTRY\s+)?%?([\w\.\-~]+)\s*\(")
_SHAPE = re.compile(r"(\w+)\[([\d,]*)\]")
_OP_ASSIGN = re.compile(r"^\s*(?:ROOT\s+)?%?([\w\.\-~]+)\s*=\s*(.*)$")
_CALLS = re.compile(r"calls=%?([\w\.\-~]+)")
_BODY = re.compile(r"body=%?([\w\.\-~]+)")
_COND = re.compile(r"condition=%?([\w\.\-~]+)")
_BRANCHES = re.compile(r"branch_computations=\{([^}]*)\}")
_TO_APPLY = re.compile(r"to_apply=%?([\w\.\-~]+)")
_GROUPS_IOTA = re.compile(r"replica_groups=\[(\d+),(\d+)\]")
_GROUPS_LIST = re.compile(r"replica_groups=\{(\{[\d,]*\})")
_CONTRACT = re.compile(r"lhs_contracting_dims=\{([\d,]*)\}")
_BATCH = re.compile(r"lhs_batch_dims=\{([\d,]*)\}")
_CONSTANT = re.compile(r"constant\((\d+)\)")

COLLECTIVE_OPS = (
    "all-reduce", "all-gather", "reduce-scatter", "all-to-all",
    "collective-permute",
)


def _shapes_in(text: str) -> List[Tuple[str, List[int]]]:
    out = []
    for m in _SHAPE.finditer(text):
        dtype, dims = m.group(1), m.group(2)
        if dtype not in _DTYPE_BYTES:
            continue
        shape = [int(d) for d in dims.split(",") if d] if dims else []
        out.append((dtype, shape))
    return out


def _nbytes(dtype: str, shape: List[int]) -> int:
    return _DTYPE_BYTES.get(dtype, 0) * math.prod(shape) if shape is not None else 0


_OPERAND_NAME = re.compile(r"%([\w\.\-~]+)")


@dataclass
class _Op:
    name: str
    opcode: str
    result: Tuple[str, List[int]]
    line: str
    operands: Tuple[str, ...] = ()
    result_all: List[Tuple[str, List[int]]] = field(default_factory=list)


@dataclass
class _Computation:
    name: str
    ops: List[_Op] = field(default_factory=list)
    symtab: Dict[str, Tuple[str, List[int]]] = field(default_factory=dict)
    # local (single-visit) costs
    flops: float = 0.0
    bytes: float = 0.0
    wire: float = 0.0
    collective_counts: Dict[str, Tuple[int, float]] = field(default_factory=dict)
    # sub-calls: (computation name, multiplier)
    calls: List[Tuple[str, float]] = field(default_factory=list)
    max_constant: int = 0


@dataclass
class HloCost:
    flops: float
    bytes: float
    wire_bytes: float
    collectives: Dict[str, Dict[str, float]]
    while_trip_counts: List[int]


def _split_assignment(rhs: str):
    """Split '<type> <opcode>(<operands>), <attrs>' robustly.

    Tuple result types nest parens and contain ``/*index=N*/`` comments, so
    this walks balanced parens instead of using a regex.
    """
    rhs = rhs.strip()
    # 1. skip the result type: either a balanced (...) tuple or one token
    if rhs.startswith("("):
        depth, i = 0, 0
        while i < len(rhs):
            if rhs[i] == "(":
                depth += 1
            elif rhs[i] == ")":
                depth -= 1
                if depth == 0:
                    break
            i += 1
        type_text, rest = rhs[: i + 1], rhs[i + 1:]
    else:
        parts = rhs.split(" ", 1)
        if len(parts) < 2:
            return None
        type_text, rest = parts
    rest = rest.strip()
    # 2. opcode = leading token up to '('
    j = rest.find("(")
    if j <= 0:
        return None
    opcode = rest[:j].strip()
    if not re.fullmatch(r"[\w\-]+", opcode or ""):
        return None
    # 3. operands = balanced paren group after opcode
    depth, k = 0, j
    while k < len(rest):
        if rest[k] == "(":
            depth += 1
        elif rest[k] == ")":
            depth -= 1
            if depth == 0:
                break
        k += 1
    operand_text = rest[j + 1: k]
    attrs = rest[k + 1:]
    return type_text, opcode, operand_text, attrs


def _parse_computations(text: str) -> Dict[str, _Computation]:
    comps: Dict[str, _Computation] = {}
    cur: Optional[_Computation] = None
    entry: Optional[str] = None
    for raw in text.splitlines():
        line = raw.rstrip()
        stripped = line.strip()
        if cur is None:
            if stripped.endswith("{") and ("->" in stripped or
                                           stripped.startswith("ENTRY")):
                m = _COMP_HEADER.match(stripped)
                if m:
                    cur = _Computation(m.group(1))
                    if stripped.startswith("ENTRY"):
                        entry = m.group(1)
                    comps[cur.name] = cur
            continue
        if stripped == "}":
            cur = None
            continue
        m = _OP_ASSIGN.match(line)
        if not m:
            continue
        name, rhs = m.groups()
        split = _split_assignment(rhs)
        if split is None:
            continue
        type_text, opcode, operand_text, attrs = split
        shapes = _shapes_in(type_text)
        result = shapes[0] if shapes else ("opaque", [])
        operands = tuple(_OPERAND_NAME.findall(operand_text))
        op = _Op(name, opcode, result, line, operands)
        op.result_all = shapes
        cur.ops.append(op)
        cur.symtab[name] = result
        if opcode == "constant":
            for c in _CONSTANT.finditer(stripped):
                cur.max_constant = max(cur.max_constant, int(c.group(1)))
    if entry is not None:
        comps["__entry__"] = comps[entry]
    return comps


def _resolve(op: _Op, comp: "_Computation", i: int):
    """Shape of the i-th operand, via the computation symbol table."""
    if i < len(op.operands):
        return comp.symtab.get(op.operands[i])
    return None


def _dot_flops(op: _Op, comp: "_Computation") -> float:
    """2 × prod(result) × prod(lhs contracting dims)."""
    lhs = _resolve(op, comp, 0)
    cm = _CONTRACT.search(op.line)
    if lhs is None or cm is None:
        return 2.0 * math.prod(op.result[1] or [0])
    cdims = [int(d) for d in cm.group(1).split(",") if d]
    try:
        contract = math.prod(lhs[1][d] for d in cdims) if cdims else 1
    except IndexError:
        contract = 1
    return 2.0 * math.prod(op.result[1] or [1]) * contract


def _conv_flops(op: _Op, comp: "_Computation") -> float:
    out = math.prod(op.result[1] or [1])
    rhs = _resolve(op, comp, 1)
    if rhs and len(rhs[1]) >= 2:
        return 2.0 * out * math.prod(rhs[1]) / max(rhs[1][-1], 1)
    return 2.0 * out


def _group_size(line: str, default: int = 2) -> int:
    m = _GROUPS_IOTA.search(line)
    if m:
        return int(m.group(2))
    m = _GROUPS_LIST.search(line)
    if m:
        return m.group(1).count(",") + 1
    return default


def _wire_bytes(opcode: str, nbytes: float, n: int) -> float:
    n = max(n, 2)
    if opcode.startswith("all-reduce"):
        return 2.0 * nbytes * (n - 1) / n
    if opcode.startswith("all-gather"):
        return nbytes * (n - 1) / n            # nbytes = gathered result
    if opcode.startswith("reduce-scatter"):
        return nbytes * (n - 1)                # nbytes = scattered result
    if opcode.startswith("all-to-all"):
        return nbytes * (n - 1) / n
    return nbytes                               # collective-permute


_PARAM_IDX = re.compile(r"parameter\((\d+)\)")


def _fusion_bytes(op: _Op, comp: "_Computation", fused: "_Computation") -> float:
    """HBM traffic of one fusion, modeling in-place loop accumulation.

    A fusion whose root is a ``dynamic-update-slice`` (or a tuple of them)
    is XLA's residual-stacking pattern: on TPU the accumulator is updated
    *in place* — traffic is the update window, not the full buffer, and
    the aliased accumulator operand is not re-read.  Everything else:
    params once + root once.
    """
    if not fused.ops:
        return 0.0
    by_name = {o.name: o for o in fused.ops}

    def through_unary(o):
        # look through dtype/layout unaries (convert/bitcast/copy): the CPU
        # backend round-trips bf16 buffers via f32 for dots, wrapping the
        # in-place DUS in converts that a TPU lowering would not emit.
        seen = 0
        while (o.opcode in ("convert", "bitcast", "copy", "reshape")
               and len(o.operands) == 1 and o.operands[0] in by_name
               and seen < 4):
            o = by_name[o.operands[0]]
            seen += 1
        return o

    root = fused.ops[-1]
    roots = [root]
    if root.opcode == "tuple":
        roots = [by_name[n] for n in root.operands if n in by_name]
    roots = [through_unary(r) for r in roots]

    aliased_params = set()
    out_bytes = 0.0
    for r in roots:
        if r.opcode in ("dynamic-update-slice", "scatter"):
            upd_i = 1 if r.opcode == "dynamic-update-slice" else 2
            upd = by_name.get(r.operands[upd_i]) \
                if len(r.operands) > upd_i else None
            win = _nbytes(*upd.result) if upd is not None else 0
            out_bytes += 2.0 * win          # read window + write window
            acc = by_name.get(r.operands[0]) if r.operands else None
            if acc is not None:
                acc = through_unary(acc)
            if acc is not None and acc.opcode == "parameter":
                m = _PARAM_IDX.search(acc.line)
                if m:
                    aliased_params.add(int(m.group(1)))
        else:
            out_bytes += _nbytes(*r.result)

    # params consumed only through a slice/gather inside the fusion are
    # read at window granularity (stacked scan params sliced per layer)
    param_ops = {}
    consumers: Dict[str, List[_Op]] = {}
    for o in fused.ops:
        if o.opcode == "parameter":
            m = _PARAM_IDX.search(o.line)
            if m:
                param_ops[o.name] = int(m.group(1))
        for operand in o.operands:
            consumers.setdefault(operand, []).append(o)
    window_params: Dict[int, float] = {}
    for pname, pidx in param_ops.items():
        # follow single-consumer unary chains (convert/bitcast/…): the CPU
        # backend interposes dtype round-trips between a stacked buffer and
        # the slice that actually reads it
        name = pname
        hops = 0
        while hops < 4:
            cons = consumers.get(name, [])
            if (len(cons) == 1
                    and cons[0].opcode in ("convert", "bitcast", "copy",
                                           "reshape")):
                name = cons[0].name
                hops += 1
                continue
            break
        cons = consumers.get(name, [])
        if cons and all(c.opcode in ("dynamic-slice", "slice", "gather")
                        and c.operands and c.operands[0] == name
                        for c in cons):
            window_params[pidx] = sum(_nbytes(*c.result) for c in cons)

    in_bytes = 0.0
    for i in range(len(op.operands)):
        if i in aliased_params:
            continue
        if i in window_params:
            in_bytes += window_params[i]
            continue
        shp = _resolve(op, comp, i)
        if shp is not None:
            in_bytes += _nbytes(*shp)
    return in_bytes + out_bytes


_SKIP_BYTES_OPS = {
    "parameter", "constant", "tuple", "get-tuple-element", "bitcast",
    "copy-start", "copy-done", "after-all", "partition-id", "replica-id",
    "while", "conditional", "call",
}

#: Ops the TPU compiler fuses into producers/consumers: charged zero HBM
#: traffic.  The CPU backend (our dry-run compiler) leaves these as loose
#: top-level ops; counting them would model CPU fusion granularity, not
#: TPU (EXPERIMENTS.md §Roofline methodology).
_FUSIBLE_ELEMENTWISE = {
    "convert", "multiply", "add", "subtract", "divide", "negate", "abs",
    "exponential", "exponential-minus-one", "log", "log-plus-one", "tanh",
    "logistic", "rsqrt", "sqrt", "power", "maximum", "minimum", "select",
    "compare", "and", "or", "not", "xor", "broadcast", "iota", "reshape",
    "transpose", "clamp", "sign", "floor", "ceil", "round-nearest-afz",
    "round-nearest-even", "erf", "expm1", "log1p", "cosine", "sine",
    "is-finite", "reduce-precision", "concatenate", "pad", "reverse",
    "shift-left", "shift-right-logical", "shift-right-arithmetic",
    "stochastic-convert", "real", "imag", "atan2", "rem", "map",
}


def _local_costs(comp: _Computation, comps: Dict[str, _Computation]) -> None:
    for op in comp.ops:
        result_bytes = sum(_nbytes(d, shp) for d, shp in op.result_all) \
            if op.result_all else _nbytes(*op.result)
        code = op.opcode
        if code == "dot":
            comp.flops += _dot_flops(op, comp)
        elif code == "convolution":
            comp.flops += _conv_flops(op, comp)
        elif any(code.startswith(c) for c in COLLECTIVE_OPS):
            if code.endswith("-done"):
                continue
            n = _group_size(op.line)
            wire = _wire_bytes(code, result_bytes, n)
            comp.wire += wire
            key = code.replace("-start", "")
            cnt, tot = comp.collective_counts.get(key, (0, 0.0))
            comp.collective_counts[key] = (cnt + 1, tot + wire)
        elif code == "fusion":
            m = _CALLS.search(op.line)
            if m:
                comp.calls.append((m.group(1), 1.0))
                fused = comps.get(m.group(1))
                if fused is not None:
                    comp.bytes += _fusion_bytes(op, comp, fused)
                    continue  # bytes fully accounted; skip generic charge
        elif code == "while":
            bm, cm_ = _BODY.search(op.line), _COND.search(op.line)
            trips = 1
            if cm_ and cm_.group(1) in comps:
                trips = max(comps[cm_.group(1)].max_constant, 1)
            if bm:
                comp.calls.append((bm.group(1), float(trips)))
                comp.calls.append(("__trip__%d" % trips, 0.0))
        elif code == "conditional":
            m = _BRANCHES.search(op.line)
            if m:
                for b in m.group(1).split(","):
                    b = b.strip().lstrip("%")
                    if b:
                        comp.calls.append((b, 1.0))
        elif code in ("call", "custom-call"):
            m = _TO_APPLY.search(op.line) or _CALLS.search(op.line)
            if m:
                comp.calls.append((m.group(1), 1.0))

        # HBM-traffic approximation: top-level op operands + result —
        # EXCEPT slicing ops, which touch only the sliced window.  A
        # dynamic-slice of the stacked (L, …) scan parameters inside the
        # layer loop reads one layer, not the whole stack; charging the
        # full operand would overcount HBM traffic by ~L×.
        if code in _SKIP_BYTES_OPS:
            continue
        if code in _FUSIBLE_ELEMENTWISE:
            continue
        if code == "copy":
            comp.bytes += result_bytes            # layout change: one write
            continue
        if code in ("dynamic-slice", "slice"):
            comp.bytes += 2.0 * result_bytes          # read window + write
            continue
        if code == "gather":
            idx = _resolve(op, comp, 1)
            comp.bytes += 2.0 * result_bytes + (_nbytes(*idx) if idx else 0)
            continue
        if code == "dynamic-update-slice":
            upd = _resolve(op, comp, 1)
            comp.bytes += 2.0 * (_nbytes(*upd) if upd else result_bytes)
            continue
        if code == "scatter":
            upd = _resolve(op, comp, 2)
            comp.bytes += 2.0 * (_nbytes(*upd) if upd else result_bytes)
            continue
        operand_bytes = 0
        for i in range(len(op.operands)):
            shp = _resolve(op, comp, i)
            if shp is not None:
                operand_bytes += _nbytes(*shp)
        comp.bytes += result_bytes + operand_bytes


def analyze_hlo(text: str) -> HloCost:
    comps = _parse_computations(text)
    for comp in comps.values():
        if comp.name != "__entry__" or comps.get(comp.name) is comp:
            pass
    seen_local = set()
    for name, comp in list(comps.items()):
        if id(comp) in seen_local:
            continue
        seen_local.add(id(comp))
        _local_costs(comp, comps)

    entry = comps.get("__entry__")
    if entry is None:  # fall back: biggest computation
        entry = max(comps.values(), key=lambda c: len(c.ops))

    totals: Dict[str, Tuple[float, float, float, Dict]] = {}
    trip_counts: List[int] = []

    def total(name: str, stack: Tuple[str, ...] = ()) -> Tuple[float, float, float, Dict]:
        if name.startswith("__trip__"):
            trip_counts.append(int(name[8:]))
            return (0.0, 0.0, 0.0, {})
        comp = comps.get(name)
        if comp is None or name in stack:
            return (0.0, 0.0, 0.0, {})
        if name in totals:
            return totals[name]
        f, b, w = comp.flops, comp.bytes, comp.wire
        colls = {k: dict(count=v[0], wire=v[1]) for k, v in
                 comp.collective_counts.items()}
        for callee, mult in comp.calls:
            cf, cb, cw, cc = total(callee, stack + (name,))
            f += mult * cf
            b += mult * cb
            w += mult * cw
            for k, v in cc.items():
                d = colls.setdefault(k, dict(count=0, wire=0.0))
                d["count"] += mult * v["count"]
                d["wire"] += mult * v["wire"]
        totals[name] = (f, b, w, colls)
        return totals[name]

    f, b, w, colls = total(entry.name)
    return HloCost(flops=f, bytes=b, wire_bytes=w, collectives=colls,
                   while_trip_counts=sorted(trip_counts, reverse=True))
