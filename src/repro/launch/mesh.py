"""Production mesh construction.

``make_production_mesh`` is a function (not a module-level constant) so that
importing this module never touches JAX device state — smoke tests see one
CPU device; only ``dryrun.py`` forces 512 host devices.

Topology: one v5e pod = 256 chips arranged ``(data=16, model=16)``; the
multi-pod mesh adds a leading pure-DP ``pod`` axis (DCN between pods, ICI
within — the ``pod`` axis only ever carries gradient all-reduces, which is
what DCN can sustain).
"""

from __future__ import annotations

from typing import Optional

import jax

__all__ = ["make_production_mesh", "make_host_mesh", "MESH_AXES"]

MESH_AXES = ("data", "model")


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_host_mesh(data: Optional[int] = None, model: int = 1):
    """Mesh over whatever devices exist (tests / single-host runs)."""
    n = len(jax.devices())
    if data is None:
        data = n // model
    return jax.make_mesh((data, model), ("data", "model"))
