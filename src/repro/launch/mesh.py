"""Production mesh construction.

``make_production_mesh`` is a function (not a module-level constant) so that
importing this module never touches JAX device state — smoke tests see one
CPU device; only ``dryrun.py`` forces 512 host devices.

Topology: one v5e pod = 256 chips arranged ``(data=16, model=16)``; the
multi-pod mesh adds a leading pure-DP ``pod`` axis (DCN between pods, ICI
within — the ``pod`` axis only ever carries gradient all-reduces, which is
what DCN can sustain).
"""

from __future__ import annotations

import os
from typing import Optional

import jax

__all__ = [
    "make_production_mesh",
    "make_host_mesh",
    "make_serving_mesh",
    "simulate_host_devices",
    "MESH_AXES",
    "SERVING_AXIS",
]

MESH_AXES = ("data", "model")

#: the tensor-parallel axis sharded serving decodes over (1-D mesh)
SERVING_AXIS = "model"


def simulate_host_devices(n: int = 4) -> None:
    """Split the host CPU into ``n`` XLA devices (bayespec-style).

    Appends ``--xla_force_host_platform_device_count`` to ``XLA_FLAGS``,
    which XLA reads at backend initialization — call this before the
    first computation (importing jax is fine; using a device is not).
    A pre-existing device-count flag is respected, so nesting harnesses
    (conftest → bench → example) never fight over the count.
    """
    os.environ.setdefault("JAX_PLATFORMS", "cpu")
    flags = os.environ.get("XLA_FLAGS", "")
    if "--xla_force_host_platform_device_count" not in flags:
        os.environ["XLA_FLAGS"] = (
            flags + f" --xla_force_host_platform_device_count={n}"
        ).strip()


def make_serving_mesh(devices: Optional[int] = None, *, offset: int = 0):
    """1-D ``("model",)`` mesh for tensor-parallel serving.

    Uses ``devices`` host devices starting at ``offset`` — replicas can
    carve disjoint sub-meshes out of one simulated host (replica 0 on
    devices 0–1, replica 1 on 2–3, ...).
    """
    avail = jax.devices()
    n = devices if devices is not None else len(avail)
    if n < 1:
        raise ValueError(f"serving mesh needs at least 1 device, got {n}")
    if offset + n > len(avail):
        raise ValueError(
            f"need devices [{offset}, {offset + n}) but only "
            f"{len(avail)} exist — call simulate_host_devices() before "
            "the first jax computation"
        )
    return jax.sharding.Mesh(avail[offset:offset + n], (SERVING_AXIS,))


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_host_mesh(data: Optional[int] = None, model: int = 1):
    """Mesh over whatever devices exist (tests / single-host runs)."""
    n = len(jax.devices())
    if data is None:
        data = n // model
    return jax.make_mesh((data, model), ("data", "model"))
