"""Partition rules: parameter / optimizer / batch / decode-state shardings.

Mesh axes (launch/mesh.py): ``pod`` (pure DP across pods), ``data``
(batch DP + FSDP parameter sharding), ``model`` (TP for d_ff and q-heads,
EP for experts, sequence-parallel residual, seq- or head-sharded KV).

Rules are functions of (tree path, leaf rank) rather than a regex table
because the same suffix appears at different ranks across families
(e.g. dense ``attn/wq`` is (L, D, H, hd) while whisper's is (L, D, H·hd)).

JAX requires sharded dimensions to divide exactly, so every builder here
is shape-aware (``fit_spec``): non-dividing dims (36/40/25/56 q-heads,
51865 vocab over a 16-way ``model`` axis) fall back to replication, and
the replicated compute is split by other means (seq-q attention sharding,
hd_v sharding for RWKV).  The residual waste shows up in the roofline
useful-FLOP ratio and is attacked in §Perf.
"""

from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.models.common import fit_spec

__all__ = [
    "param_spec",
    "param_shardings",
    "opt_state_shardings",
    "batch_specs",
    "batch_shardings",
    "decode_state_shardings",
    "serving_tp_shardings",
    "named",
]


def named(mesh: Mesh, spec: P, shape: Optional[Tuple[int, ...]] = None
          ) -> NamedSharding:
    """NamedSharding with missing axes dropped and divisibility enforced.

    Without ``shape``, only axis-name filtering happens (use for scalars /
    always-divisible cases); with ``shape``, ``fit_spec`` guarantees a
    legal sharding for any architecture (JAX requires exact divisibility).
    """
    if shape is not None:
        return NamedSharding(mesh, fit_spec(mesh, spec, shape))
    axes = set(mesh.axis_names)

    def keep(entry):
        if entry is None:
            return None
        if isinstance(entry, (tuple, list)):
            kept = tuple(a for a in entry if a in axes)
            return kept if kept else None
        return entry if entry in axes else None

    return NamedSharding(mesh, P(*(keep(e) for e in spec)))


# --------------------------------------------------------------------------
# parameters
# --------------------------------------------------------------------------

def param_spec(path: str, ndim: int, *, heads_divisible: bool = True
               ) -> P:  # noqa: C901 (rule table)
    """PartitionSpec for one parameter leaf.

    ``path`` is the slash-joined tree path; stacked layer leaves have a
    leading L dimension (never sharded — it is the scan axis).

    ``heads_divisible=False`` (§Perf-B5): the arch's q-heads don't divide
    the model axis, so attention runs sequence-parallel — ``wo``'s input
    dim must NOT be model-sharded (a model-sharded contraction there
    forces a (B,S,D) all-reduce every layer).
    """
    def stacked(*tail):
        # leaf may or may not carry the leading (L,) scan dim
        if ndim == len(tail) + 1:
            return P(None, *tail)
        assert ndim == len(tail), (path, ndim, tail)
        return P(*tail)

    leaf = path.split("/")[-1]

    # ---- embeddings -------------------------------------------------------
    if leaf in ("embed", "unembed"):
        return P("model", "data")
    if leaf == "pos_embed":
        return P(None, "data")

    # ---- attention (dense 4D: (L, D, H, hd); whisper 3D: (L, D, H*hd)) ----
    if "attn" in path:
        if leaf == "wq":
            return stacked("data", "model", None) if ndim >= 4 else \
                stacked("data", "model")
        if leaf in ("wk", "wv"):
            # kv heads < model axis on every arch: replicate over model,
            # FSDP-shard the input dim over data.  (whisper: H==K, still
            # small; same rule.)
            return stacked("data", None, None) if ndim >= 4 else \
                stacked("data", None)
        if leaf == "wo":
            if not heads_divisible:
                return stacked(None, "data")
            return stacked("model", "data")
        if leaf == "bq":
            return stacked("model", None) if ndim >= 3 else stacked(None, None)
        if leaf in ("bk", "bv"):
            return stacked(None, None)
        if leaf == "bo":
            return stacked(None)
        if leaf in ("q_norm", "k_norm"):
            return stacked(None)

    # ---- MoE ---------------------------------------------------------------
    if "moe" in path:
        if leaf == "router":
            return stacked(None, None)
        if leaf in ("wg", "wu"):
            return stacked("model", None, "data")
        if leaf == "wd":
            return stacked("model", "data", None)
        if leaf in ("swg", "swu"):
            return stacked("data", "model")
        if leaf == "swd":
            return stacked("model", "data")

    # ---- dense / shared MLP -------------------------------------------------
    if "mlp" in path:
        if leaf in ("wg", "wu", "w1", "wck"):
            return stacked("data", "model")
        if leaf in ("wd", "w2", "wcv"):
            return stacked("model", "data")
        if leaf in ("b1",):
            return stacked("model")
        if leaf in ("b2",):
            return stacked(None)

    # ---- RWKV time/channel mix ----------------------------------------------
    if leaf in ("wr", "wk", "wv", "wg", "wcr") and ndim == 3:
        return stacked("data", "model")
    if leaf == "wo" and ndim == 3:
        return stacked("model", "data")
    if leaf in ("wck",):
        return stacked("data", "model")
    if leaf in ("wcv",):
        return stacked("model", "data")
    if leaf == "tm_w1":
        return stacked("data", None)
    if leaf == "tm_w2":
        return stacked(None, None, "data")
    if leaf == "dw1":
        return stacked("data", None)
    if leaf == "dw2":
        return stacked(None, "data")
    if leaf == "u" and ndim == 3:
        return stacked("model", None)
    if leaf == "mu_rkvwg":
        return stacked(None, None)

    # ---- hybrid SSM ----------------------------------------------------------
    if "ssm" in path:
        if leaf == "w_in":
            return stacked("data", "model")
        if leaf == "w_dt":
            return stacked("data", "model")
        if leaf in ("w_B", "w_C"):
            return stacked("model", None)
        if leaf == "A_log":
            return stacked("model", None)
        if leaf in ("D_skip", "dt_bias"):
            return stacked("model")
        if leaf == "w_out":
            return stacked("model", "data")

    # ---- everything else (norms, scalars, small vectors): replicated --------
    return P(*([None] * ndim))


def _path_str(path) -> str:
    return "/".join(
        str(getattr(k, "key", getattr(k, "idx", k))) for k in path
    )


def param_shardings(params_shape, mesh: Mesh, *, mode: str = "train",
                    heads_divisible: bool = True):
    """Map a params (shape-)tree to NamedShardings.

    ``mode="serve"`` (§Perf-C1): drop the FSDP ``data`` sharding for dense
    weights — serving has no optimizer state, so dense params fit
    model-sharded and replicate over ``data``, eliminating the per-token
    all-gathers a decode step would otherwise pay every layer.  MoE expert
    weights keep their 2-D sharding (they don't fit otherwise).
    """
    model_size = dict(mesh.shape).get("model", 1)

    def visit(path, leaf):
        p = _path_str(path)
        spec = param_spec(p, len(leaf.shape),
                          heads_divisible=heads_divisible)
        if mode == "serve" and "moe" not in p:
            fitted = fit_spec(mesh, spec, leaf.shape)
            ents = list(fitted) + [None] * (len(leaf.shape) - len(fitted))
            used_model = any(
                "model" in (e if isinstance(e, tuple) else (e,))
                for e in ents if e is not None
            )
            out = []
            for dim, e in zip(leaf.shape, ents):
                names = e if isinstance(e, tuple) else (e,)
                if e is not None and "data" in names:
                    # re-home the FSDP shard onto the model axis (compute
                    # stays local / cheap psum) instead of replicating
                    if not used_model and dim % model_size == 0:
                        out.append("model")
                        used_model = True
                    else:
                        out.append(None)
                else:
                    out.append(e)
            return named(mesh, P(*out), leaf.shape)
        return named(mesh, spec, leaf.shape)

    return jax.tree_util.tree_map_with_path(visit, params_shape)


def opt_state_shardings(opt_shape, mesh: Mesh, *,
                        heads_divisible: bool = True):
    """Adam moments shard exactly like their parameters."""
    def visit(path, leaf):
        p = _path_str(path)
        if p.startswith(("mu/", "nu/")):
            p = p.split("/", 1)[1]
        if leaf.shape == ():
            return named(mesh, P())
        return named(mesh, param_spec(p, len(leaf.shape),
                                      heads_divisible=heads_divisible),
                     leaf.shape)

    return jax.tree_util.tree_map_with_path(visit, opt_shape)


# --------------------------------------------------------------------------
# batches & decode state
# --------------------------------------------------------------------------

def batch_specs(batch_shape, *, batch_divisible: bool = True) -> Dict[str, P]:
    """Specs for a train/prefill batch dict (tokens/targets/mask/frames…)."""
    b_axes: Any = ("pod", "data") if batch_divisible else None
    specs = {}
    for key, leaf in batch_shape.items():
        nd = len(leaf.shape)
        if key in ("tokens", "targets", "loss_mask"):
            specs[key] = P(b_axes, "model") if nd == 2 else P(b_axes)
        elif key in ("frames", "patch_embeds"):
            specs[key] = P(b_axes, None, None)
        else:
            specs[key] = P(*([None] * nd))
    return specs


def batch_shardings(batch_shape, mesh: Mesh, **kw):
    return {
        k: named(mesh, s, batch_shape[k].shape)
        for k, s in batch_specs(batch_shape, **kw).items()
    }


def decode_state_shardings(state_shape, mesh: Mesh, *, layout: str = "seq",
                           batch_divisible: bool = True):
    """Shardings for the decode-state dict of any family.

    ``layout="seq"`` (default) shards the KV cache over the sequence axis
    (flash-decoding style): always divisible, no KV-head padding, partial
    attention merged by the sharded softmax.  ``layout="heads"`` is only
    legal when kv_heads divides the model axis.
    """
    b_axes: Any = ("pod", "data") if batch_divisible else None

    def visit(path, leaf):
        key = _path_str(path)
        nd = len(leaf.shape)
        shp = leaf.shape
        if key.startswith(("cache_k", "cache_v")):
            if layout == "seq":
                return named(mesh, P(None, b_axes, "model", None, None), shp)
            return named(mesh, P(None, b_axes, None, "model", None), shp)
        if key.startswith(("xk", "xv")):           # whisper cross-attn K/V
            return named(mesh, P(None, b_axes, None, "model", None), shp)
        if key.startswith("wkv"):                   # rwkv (L,B,H,hd,hd_v)
            return named(mesh, P(None, b_axes, None, None, "model"), shp)
        if key.startswith("ssm_h"):                 # hymba (L,B,di,N)
            return named(mesh, P(None, b_axes, "model", None), shp)
        if key.startswith(("tm_prev", "cm_prev")):  # rwkv shifts (L,B,D)
            return named(mesh, P(None, b_axes, None), shp)
        if key.startswith("pos"):
            return named(mesh, P(b_axes), shp)
        return named(mesh, P(*([None] * nd)))

    return jax.tree_util.tree_map_with_path(visit, state_shape)


# --------------------------------------------------------------------------
# sharded serving (tensor-parallel paged decode)
# --------------------------------------------------------------------------

def serving_tp_shardings(mesh: Mesh, specs):
    """NamedShardings for a model's serving-TP spec pytree.

    ``specs`` comes from a model's ``tp_param_specs()`` /
    ``tp_pool_specs()`` — a pytree of :class:`PartitionSpec` matching the
    params / paged-store structure.  These drive both the ``device_put``
    placement of params and the bound page pool (so every device holds
    its head shard of each physical page) and, spec-for-spec, the
    ``shard_map`` in/out specs of the paged decode step.  Only call when
    ``model.tp_supported(n)`` — the specs are exact-divisibility by
    contract, never fit-adjusted (a silently replicated leaf would make
    ``shard_map`` mis-slice it).
    """
    return jax.tree.map(
        lambda s: named(mesh, s), specs,
        is_leaf=lambda x: isinstance(x, P),
    )
