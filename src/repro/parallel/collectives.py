"""Distributed-optimization tricks: compressed gradient reduction + overlap.

``compressed_psum`` — int8-quantized gradient all-reduce with per-block
scales, for the ``pod`` axis (cross-pod DCN is the bandwidth-starved hop
at 1000+ node scale): wire bytes drop ~3.5× vs bf16 (7× vs f32) at the
cost of ≤1/254 relative quantization error per block.  Built on
``shard_map`` + ``all_gather`` of the int8 payload so it lowers on any
mesh.  ``ErrorFeedback`` accumulates the quantization residual into the
next step's gradient (Seide et al.; keeps SGD unbiased over time).

``microbatch_overlap_note``: compute/comm overlap for FSDP gathers and
grad reductions is delegated to XLA's latency-hiding scheduler — the
dry-run HLO already emits ``all-gather-start``/``-done`` pairs that
overlap with the layer matmuls; what this module adds is the *semantic*
knob (what to compress, where the residual lives).
"""

from __future__ import annotations


import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.compat import shard_map

__all__ = ["quantize_int8", "dequantize_int8", "compressed_psum",
           "maybe_psum", "ErrorFeedback", "compressed_grad_tree"]

BLOCK = 256


def maybe_psum(x: jnp.ndarray, axis_name: str = "model") -> jnp.ndarray:
    """``psum(x, axis_name)`` when the axis is bound, identity otherwise.

    Model bodies call this after every row-sharded matmul so *one*
    definition serves both execution modes: inside ``shard_map`` the
    axis name resolves and partial products reduce across the mesh;
    under plain ``jit`` (single-device serving, training, tests) the
    unbound name raises ``NameError`` at trace time and the full-width
    product passes through untouched.  Integer operands reduce exactly
    (psum of int32 is order-independent), which is what lets the
    sharded-vs-single-device differential tests demand byte equality.
    """
    try:
        return jax.lax.psum(x, axis_name)
    except NameError:
        return x


def quantize_int8(x: jnp.ndarray, block: int = BLOCK):
    """Flat per-block symmetric int8 quantization → (q, scales, pad)."""
    flat = x.reshape(-1)
    pad = (-flat.shape[0]) % block
    flat = jnp.pad(flat, (0, pad))
    blocks = flat.reshape(-1, block).astype(jnp.float32)
    scale = jnp.max(jnp.abs(blocks), axis=1, keepdims=True) / 127.0
    scale = jnp.maximum(scale, 1e-12)
    q = jnp.clip(jnp.round(blocks / scale), -127, 127).astype(jnp.int8)
    return q, scale.astype(jnp.float32), pad


def dequantize_int8(q, scale, pad, shape):
    flat = (q.astype(jnp.float32) * scale).reshape(-1)
    if pad:
        flat = flat[:-pad]
    return flat.reshape(shape)


def compressed_psum(x: jnp.ndarray, axis_name: str):
    """int8 all-gather + local dequant-sum ≅ psum(x) with ~3.5x less wire.

    Call inside shard_map.  Exact psum wire (bf16 ring): 2·(n-1)/n·B;
    int8 gather wire: (n-1)/n·(B/2 + scales) — plus the result needs no
    second pass because every member reconstructs the sum locally.
    """
    q, scale, pad = quantize_int8(x)
    qs = jax.lax.all_gather(q, axis_name)          # (n, blocks, BLOCK) int8
    ss = jax.lax.all_gather(scale, axis_name)
    total = jnp.sum(qs.astype(jnp.float32) * ss, axis=0)
    flat = total.reshape(-1)
    if pad:
        flat = flat[:-pad]
    return flat.reshape(x.shape).astype(x.dtype)


class ErrorFeedback:
    """Residual accumulator for biased compressed reductions."""

    @staticmethod
    def init(tree):
        return jax.tree.map(lambda g: jnp.zeros(g.shape, jnp.float32), tree)

    @staticmethod
    def apply(grads, residual):
        """Returns (corrected_grads, fn(compressed)->new_residual)."""
        corrected = jax.tree.map(
            lambda g, r: g.astype(jnp.float32) + r, grads, residual
        )

        def update(compressed):
            return jax.tree.map(
                lambda c, co: co - c.astype(jnp.float32), compressed, corrected
            )

        return corrected, update


def compressed_grad_tree(grads, mesh, axis_name: str = "pod"):
    """Compressed psum of a gradient pytree over one mesh axis.

    Gradients are assumed already sharded/reduced over the other axes
    (GSPMD handles those); this performs the cross-pod (DCN) hop with
    int8 payloads via shard_map.
    """
    if mesh is None or axis_name not in mesh.axis_names:
        return grads

    other = tuple(a for a in mesh.axis_names if a != axis_name)

    def one(g):
        spec_in = P()          # replicated view over the compressed axis

        def fn(gl):
            return compressed_psum(gl, axis_name)

        return shard_map(
            fn, mesh=mesh,
            in_specs=P(*([None] * g.ndim)),
            out_specs=P(*([None] * g.ndim)),
            axis_names={axis_name},
            check_vma=False,
        )(g)

    return jax.tree.map(one, grads)
