from .sharding import (
    batch_shardings,
    batch_specs,
    decode_state_shardings,
    named,
    opt_state_shardings,
    param_shardings,
    param_spec,
)

__all__ = [
    "batch_shardings", "batch_specs", "decode_state_shardings", "named",
    "opt_state_shardings", "param_shardings", "param_spec",
]

from .collectives import (  # noqa: E402
    ErrorFeedback,
    compressed_grad_tree,
    compressed_psum,
    dequantize_int8,
    quantize_int8,
)
__all__ += ["ErrorFeedback", "compressed_grad_tree", "compressed_psum",
            "dequantize_int8", "quantize_int8"]
