"""Sharded serving: tensor-parallel decode + data-parallel replicas.

Splits the host CPU into 4 simulated XLA devices, then demos the two
sharding planes:

1. **Tensor parallel** — one engine whose attention params and KV page
   pool are sharded over a 4-device ``("model",)`` mesh; every decode
   step runs the paged-attention kernel per-shard and ``psum``s the
   logits.  The stream is the same stream, just computed across shards.

2. **DP x TP** — a :class:`ReplicaSet` of two engines, each TP-2 over a
   *disjoint* sub-mesh (devices 0-1 / 2-3), behind tenant-sticky
   routing.  Mid-run a mesh member under replica 0 dies *silently*; the
   heartbeat monitor reaps it on the executor's virtual clock and every
   stranded request re-homes to replica 1 and completes — sampling is
   keyed by (seed, token index), so re-homed streams stay byte-identical
   to an undisturbed run.

    PYTHONPATH=src python examples/serve_sharded.py
"""

import dataclasses

from repro.launch.mesh import make_serving_mesh, simulate_host_devices

# must run before the first computation: XLA reads the device-count
# flag once, at backend initialization
simulate_host_devices(4)

import jax  # noqa: E402
import numpy as np  # noqa: E402

from repro.configs import get_reduced  # noqa: E402
from repro.core import SimExecutor  # noqa: E402
from repro.models import build_model  # noqa: E402
from repro.runtime import Request, ServingEngine  # noqa: E402
from repro.runtime.replica import ReplicaSet  # noqa: E402
from repro.runtime.serve_loop import ServerConfig  # noqa: E402


def tp_model():
    # a TP-capable head layout: 4 query heads over 4 KV heads, so mesh
    # sizes 1/2/4 all divide both head axes (the stock reduced config
    # has a single KV head and would auto-fall back to dense)
    cfg = dataclasses.replace(
        get_reduced("qwen2.5-32b"), num_heads=4, num_kv_heads=4, head_dim=16,
    )
    model = build_model(cfg)
    return cfg, model, model.init(jax.random.PRNGKey(0))


def requests(vocab, n, *, tenants=("alice",), seed=0, base_id=0):
    rng = np.random.default_rng(seed)
    return [Request(
        prompt=rng.integers(0, vocab, (8,)).astype(np.int32),
        max_new_tokens=6, request_id=base_id + i,
        tenant=tenants[i % len(tenants)],
    ) for i in range(n)]


def demo_tensor_parallel():
    cfg, model, params = tp_model()
    engine = ServingEngine(
        model, params,
        ServerConfig(max_batch=2, max_seq=48, kv_mode="paged"),
        mesh=make_serving_mesh(4),
    )
    reqs = requests(cfg.vocab_size, 4)
    for r in reqs:
        engine.submit(r)
    engine.drain()
    stats = engine.serving_stats()
    print(f"[tp] {len(reqs)} requests over {stats['tp_shards']} shards: "
          f"{sum(stats['completed_total'].values())} completed, "
          f"0 errors = {all(r.error is None for r in reqs)}")
    assert engine.kv.shard_stats()["live_pages_per_shard"] == 0


def demo_dp_times_tp():
    cfg, model, params = tp_model()
    sim = SimExecutor(seed=0)
    replicas = [ServingEngine(
        model, params,
        ServerConfig(max_batch=2, max_seq=48, kv_mode="paged",
                     step_time_s=0.01),
        executor=sim,
        mesh=make_serving_mesh(2, offset=i * 2),   # disjoint sub-meshes
    ) for i in range(2)]
    rs = ReplicaSet(replicas, heartbeat_timeout_s=0.05)

    reqs = requests(cfg.vocab_size, 8,
                    tenants=("alice", "bob", "carol"), seed=1)
    for r in reqs:
        rs.submit(r)
    homes = {t: rs.route(t) for t in ("alice", "bob", "carol")}
    print(f"[dp] tenant homes: {homes}")

    for _ in range(3):                             # a few steps of progress
        rs.step()
        sim.sleep(rs.step_time_s)
    rs.kill_mesh_member(0)                         # silent device death
    rs.drain()

    st = rs.replica_stats()
    print(f"[dp] mesh member died: heartbeat reaps={st['heartbeat_reaps']}, "
          f"re-homed={st['rehomed_total']}, orphaned={st['orphaned']}")
    print(f"[dp] all {len(reqs)} requests completed: "
          f"{all(r.done and r.error is None for r in reqs)}")
    for i, p in enumerate(st["per_replica"]):
        print(f"     replica {i}: alive={p['alive']} "
              f"tp_shards={p['tp_shards']} completed={p['completed']} "
              f"live_pages={p['live_pages']}")


if __name__ == "__main__":
    demo_tensor_parallel()
    demo_dp_times_tp()
