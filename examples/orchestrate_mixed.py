"""Unified orchestration: decode + training + batch on one worker pool,
with metrics-driven elastic autoscaling.

One :class:`~repro.core.sim.SimExecutor` clock drives the full stack:

1. a :class:`~repro.runtime.serve_loop.ServingEngine` decoding a stream
   of requests (the latency-sensitive class, priority lane + preemption
   rights over batch),
2. a real :class:`~repro.runtime.train_loop.TrainStepper` running
   optimizer steps as pool tasks,
3. a bag of sandbox-batch jobs (the throughput class),

while an :class:`~repro.runtime.elastic.ElasticAutoscaler` watches queue
depth / admit-wait / busy fractions and scales the fleet:

* at t=0.25 a **load spike** lands (a burst of decode requests + batch
  jobs) — the backlog crosses ``queue_high`` and workers are added;
* at t=0.45 a **node dies**; the heartbeat reaper requeues its task
  exactly once and replaces the worker;
* when the burst drains, sustained idleness (``idle_ticks``) scales the
  fleet back down.

Every decision and task transition is virtual-clock deterministic: run
it twice and the printed trace is byte-identical.

    PYTHONPATH=src python examples/orchestrate_mixed.py
"""

import random

import jax

from repro.configs import get_reduced
from repro.core import ServerlessScheduler, SimExecutor
from repro.core.tasks import checkpoint
from repro.data import DataConfig, Loader, SyntheticLM
from repro.models import build_model
from repro.runtime import (ElasticAutoscaler, Request, ServingEngine,
                           Trainer, TrainerConfig, WorkloadOrchestrator)
from repro.runtime.elastic import AutoscalerConfig
from repro.runtime.serve_loop import ServerConfig


def main():
    sim = SimExecutor(seed=42)
    rng = random.Random(7)

    # --- serving plane: a reduced model decoding on the shared pool -------
    scfg = get_reduced("gemma2-9b")
    smodel = build_model(scfg)
    engine = ServingEngine(
        smodel, smodel.init(jax.random.PRNGKey(0)),
        ServerConfig(max_batch=3, max_seq=48, step_time_s=0.01),
        executor=sim,
    )

    def req(i, n=4):
        import numpy as np
        prompt = np.asarray([rng.randrange(scfg.vocab_size)
                             for _ in range(4)], np.int32)
        return Request(prompt=prompt, max_new_tokens=n, request_id=i)

    # --- training plane: a real TrainStepper as pool tasks -----------------
    tcfg = get_reduced("gemma2-9b")
    dc = DataConfig(global_batch=4, seq_len=16, vocab_size=tcfg.vocab_size)
    trainer = Trainer(build_model(tcfg), Loader(SyntheticLM(dc), dc),
                      TrainerConfig(total_steps=6, log_every=2,
                                    ckpt_every=100))
    params, opt = trainer.init_state(jax.random.PRNGKey(0))
    stepper = trainer.stepper(params, opt)

    # --- the shared pool + autoscaler + orchestrator -----------------------
    sched = ServerlessScheduler(workers=2, executor=sim)
    sched.enable_heartbeats(timeout_s=0.3, replace_dead=True)
    sched.start()
    auto = ElasticAutoscaler(sched, serving=engine, cfg=AutoscalerConfig(
        min_workers=1, max_workers=6, queue_high=3, idle_ticks=4,
        cooldown_ticks=2))
    orch = WorkloadOrchestrator(sched, serving=engine, stepper=stepper,
                                autoscaler=auto)

    def batch_body(sleeps=4):
        def body():
            for _ in range(sleeps):
                checkpoint()            # cooperative preemption point
                sim.sleep(0.01)
            return sleeps

        return body

    # steady state: a few requests + jobs from t=0
    for i in range(4):
        engine.submit(req(i))
    jobs = [orch.submit_batch(batch_body(), name=f"steady{i}")
            for i in range(2)]

    # t=0.25: load spike — decode burst + batch burst
    def spike():
        print(f"[t={sim.now():.2f}] LOAD SPIKE: +6 requests, +4 jobs")
        for i in range(100, 106):
            engine.submit(req(i))
        for i in range(4):
            jobs.append(orch.submit_batch(batch_body(6), name=f"spike{i}"))

    sim.call_at(0.25, spike)

    # t=0.45: node death — heartbeats reap + replace it
    def node_death():
        print(f"[t={sim.now():.2f}] NODE DEATH: killing w0")
        sim.kill("w0")

    sim.call_at(0.45, node_death)

    # pumps: orchestration ticks, heartbeat reaper, and everything runs
    for k in range(200):
        sim.call_at(0.02 * k + 0.005, orch.tick)
    for k in range(1, 80):
        sim.call_at(0.05 * k, sched.check_heartbeats)
    sim.run()
    orch.tick()
    sched.drain(timeout=120)
    sim.run()

    # --- report -------------------------------------------------------------
    print(f"\n[t={sim.now():.2f}] drained")
    print(f"  decode   : {len(engine.completed)} requests completed")
    print(f"  training : {stepper.step} steps"
          f" (loss {trainer.metrics_log[-1]['loss']:.4f})")
    print(f"  batch    : {sum(1 for j in jobs if j.state == 'done')}"
          f"/{len(jobs)} jobs done,"
          f" {orch.preemptions_total} preemptions for decode")
    print("\nautoscaler decisions (scale events only):")
    for d in auto.decisions:
        if d.action != "hold":
            print(f"  t={d.t:5.2f}  {d.action:18s} {d.reason:22s}"
                  f" queue={d.queue_depth:3d} workers={d.workers}")
    st = auto.elastic_stats()
    print(f"\nfleet: {st['workers_active']} active workers"
          f" (scaled up {st['scale_up_total']}x,"
          f" down {st['scale_down_total']}x);"
          f" pool healthy={st['pool_healthy']}")
    assert all(j.state == "done" for j in jobs)
    assert stepper.done()
    trainer.loader.stop()


if __name__ == "__main__":
    main()
