"""Quickstart: build an architecture, train, checkpoint, restore, decode.

    PYTHONPATH=src python examples/quickstart.py
"""

import tempfile

import jax
import jax.numpy as jnp

from repro.checkpoint import CheckpointManager
from repro.configs import get_reduced
from repro.core.gofer import Gofer
from repro.data import DataConfig, Loader, SyntheticLM
from repro.models import build_model
from repro.optim import ScheduleConfig
from repro.runtime import Trainer, TrainerConfig


def main():
    cfg = get_reduced("gemma2-9b")               # --arch selects any of 10
    model = build_model(cfg)
    print(f"arch={cfg.arch_id}  params={cfg.param_count():,}")

    # --- train a few steps on the synthetic pipeline -----------------------
    dc = DataConfig(global_batch=8, seq_len=32, vocab_size=cfg.vocab_size)
    loader = Loader(SyntheticLM(dc), dc)
    ckpt = CheckpointManager(
        Gofer.for_root("ckpt", tempfile.mkdtemp(), write=True))
    trainer = Trainer(
        model, loader,
        TrainerConfig(total_steps=30, log_every=10, ckpt_every=15,
                      schedule=ScheduleConfig(peak_lr=3e-3, warmup_steps=5)),
        ckpt=ckpt,
    )
    params, opt = trainer.init_state(jax.random.PRNGKey(0))
    params, opt = trainer.run(params, opt)
    loader.stop()
    for m in trainer.metrics_log:
        print(f"  step {m['step']:3d}  loss {m['loss']:.4f}")

    # --- restore from the SELF checkpoint (paper §IV.B loader) -------------
    step, tree, _ = ckpt.restore_latest({"params": params, "opt": opt})
    print(f"restored step {step} from SELF checkpoint")

    # --- greedy decode ------------------------------------------------------
    prompt = jnp.asarray([[5, 17, 40, 2]], jnp.int32)
    state, logits = model.prefill(tree["params"], prompt, max_seq=16)
    out = []
    tok = jnp.argmax(logits, -1).astype(jnp.int32)
    for _ in range(8):
        state, logits = model.decode_step(tree["params"], state, tok)
        tok = jnp.argmax(logits, -1).astype(jnp.int32)
        out.append(int(tok[0]))
    print("decoded:", out)


if __name__ == "__main__":
    main()
