"""End-to-end serving driver (the paper-representative example):

continuous batching over the SEE++ **paged KV arena** — the arena's page
pool is the physical KV store, decode attention runs through the Pallas
paged-attention kernel, and sampled token streams are reproducible by
seed — with the paper's legacy-vs-modern allocator A/B, a sandboxed user
post-processor, and a mid-flight batch kill to show that in paged mode
recovery is a page-table edit (sequences resume off their surviving
pages with zero re-prefill).

    PYTHONPATH=src python examples/serve_paged.py
"""

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_reduced
from repro.models import build_model
from repro.runtime import Request, Server, ServerConfig


def main():
    cfg = get_reduced("qwen2.5-32b")
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    rng = np.random.default_rng(7)

    def dedupe(tokens):                     # user code, runs in the Sentry
        keep = jnp.concatenate(
            [jnp.ones(1, bool), tokens[1:] != tokens[:-1]])
        return jnp.where(keep, tokens, -1)

    def make_requests():
        r = np.random.default_rng(7)
        return [
            Request(prompt=r.integers(0, cfg.vocab_size, (6,)).astype(np.int32),
                    max_new_tokens=8, request_id=i,
                    temperature=0.8 if i % 2 else 0.0, top_k=8, seed=100 + i,
                    postprocess=dedupe if i == 0 else None)
            for i in range(6)
        ]

    # -- legacy-vs-modern allocator A/B over the paged decode plane -----
    for legacy in (True, False):
        srv = Server(model, params,
                     ServerConfig(max_batch=4, max_seq=96, mm_legacy=legacy))
        done = srv.run(make_requests())
        stats = srv.arena_report()["mm_stats"]
        name = "legacy" if legacy else "modern"
        print(f"[{name}] {len(done)} requests served "
              f"(kv_mode={srv.engine.kv_mode}); "
              f"host VMAs hw={stats['host_vma_high_water']} "
              f"faults={stats['faults']}")
        srv.close()
    baseline = {r.request_id: tuple(r.tokens)
                for r in sorted(done, key=lambda r: r.request_id)}
    print("first request postprocessed (sandboxed):",
          sorted(done, key=lambda r: r.request_id)[0].tokens)

    # -- eviction is a table edit: kill the batch mid-flight ------------
    srv = Server(model, params, ServerConfig(max_batch=4, max_seq=96))
    reqs = make_requests()
    for r in reqs:
        srv.submit(r)
    srv.step()                              # everything admitted + decoding
    srv.engine.kill_batch()                 # chaos: evict every live slot
    srv.drain()
    stats = srv.engine.serving_stats()
    resumed = {r.request_id: tuple(r.tokens)
               for r in sorted(reqs, key=lambda r: r.request_id)}
    print(f"[kill] batch killed mid-flight: evicted={stats['evicted_total']} "
          f"resumed={stats['resumed_total']} off surviving pages "
          f"(pages allocated={stats['kv_pages_allocated_total']} "
          f"freed={stats['kv_pages_freed_total']})")
    print("[kill] seeded streams identical to the un-killed run:",
          resumed == baseline)
    srv.close()


if __name__ == "__main__":
    main()
