"""End-to-end serving driver (the paper-representative example):

continuous batching over the SEE++ **paged KV arena**, with the paper's
legacy-vs-modern allocator A/B and a sandboxed user post-processor.

    PYTHONPATH=src python examples/serve_paged.py
"""

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_reduced
from repro.models import build_model
from repro.runtime import Request, Server, ServerConfig


def main():
    cfg = get_reduced("qwen2.5-32b")
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    rng = np.random.default_rng(7)

    def dedupe(tokens):                     # user code, runs in the Sentry
        keep = jnp.concatenate(
            [jnp.ones(1, bool), tokens[1:] != tokens[:-1]])
        return jnp.where(keep, tokens, -1)

    for legacy in (True, False):
        srv = Server(model, params,
                     ServerConfig(max_batch=4, max_seq=96, mm_legacy=legacy))
        reqs = [
            Request(prompt=rng.integers(0, cfg.vocab_size, (6,)).astype(np.int32),
                    max_new_tokens=8, request_id=i,
                    postprocess=dedupe if i == 0 else None)
            for i in range(6)
        ]
        done = srv.run(reqs)
        stats = srv.arena_report()["mm_stats"]
        name = "legacy" if legacy else "modern"
        print(f"[{name}] {len(done)} requests served; "
              f"host VMAs hw={stats['host_vma_high_water']} "
              f"faults={stats['faults']}")
    print("first request postprocessed (sandboxed):",
          sorted(done, key=lambda r: r.request_id)[0].tokens)


if __name__ == "__main__":
    main()
