"""Training example with fault injection: a worker dies mid-run and the
trainer restarts from the latest SELF checkpoint.

    PYTHONPATH=src python examples/train_lm.py [--arch rwkv6-3b]
"""

import argparse
import tempfile

import jax

from repro.checkpoint import CheckpointManager
from repro.configs import get_reduced, list_archs
from repro.core.gofer import Gofer
from repro.data import DataConfig, Loader, SyntheticLM
from repro.models import build_model
from repro.optim import ScheduleConfig
from repro.runtime import (FailureInjector, HeartbeatMonitor,
                           StragglerDetector, Trainer, TrainerConfig)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="hymba-1.5b", choices=list_archs())
    ap.add_argument("--steps", type=int, default=60)
    args = ap.parse_args()

    cfg = get_reduced(args.arch)
    model = build_model(cfg)
    dc = DataConfig(global_batch=8, seq_len=32, vocab_size=cfg.vocab_size)
    loader = Loader(SyntheticLM(dc), dc)
    ckpt = CheckpointManager(
        Gofer.for_root("ckpt", tempfile.mkdtemp(), write=True))
    trainer = Trainer(
        model, loader,
        TrainerConfig(total_steps=args.steps, log_every=10, ckpt_every=20,
                      schedule=ScheduleConfig(peak_lr=3e-3, warmup_steps=10)),
        ckpt=ckpt,
        monitor=HeartbeatMonitor([f"host{i}" for i in range(4)]),
        stragglers=StragglerDetector(),
        injector=FailureInjector(fail_at={args.steps // 2: ["host2"]}),
    )
    params, opt = trainer.init_state(jax.random.PRNGKey(0))
    params, opt = trainer.run(params, opt)
    loader.stop()
    for m in trainer.metrics_log:
        print(f"  step {m['step']:3d}  loss {m['loss']:.4f}")
    print(f"worker failure at step {args.steps // 2} -> "
          f"{trainer.restarts} restart(s) from checkpoint; "
          f"final checkpoints: {ckpt.all_steps()}")


if __name__ == "__main__":
    main()
