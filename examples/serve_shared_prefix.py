"""Cross-tenant prefix sharing: two tenants, one system prompt.

Both tenants' requests open with the same 24-token system prompt.  With
``ServerConfig.prefix_sharing`` (on by default in paged mode) the first
request prefills the header once; every later request maps those K/V
pages read-only out of the arena's radix index and prefills only its own
suffix.  The first divergent write copy-on-writes the shared partial
page, so tenants never see each other's bytes — and the streams are
byte-identical to a run with sharing disabled.

    PYTHONPATH=src python examples/serve_shared_prefix.py
"""

import jax
import numpy as np

from repro.configs import get_reduced
from repro.models import build_model
from repro.runtime import Request, Server, ServerConfig


def make_requests(vocab):
    rng = np.random.default_rng(42)
    system_prompt = rng.integers(0, vocab, (24,))    # shared by everyone
    reqs = []
    for i in range(8):
        user_turn = rng.integers(0, vocab, (8,))     # per-request suffix
        reqs.append(Request(
            prompt=np.concatenate([system_prompt, user_turn]).astype(np.int32),
            max_new_tokens=6, request_id=i,
            tenant=("alice", "bob")[i % 2],          # cross-tenant!
        ))
    return reqs


def main():
    cfg = get_reduced("qwen2.5-32b")
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))

    streams = {}
    for sharing in (True, False):
        srv = Server(model, params, ServerConfig(
            max_batch=2, max_seq=64,
            prefix_sharing=sharing,
            # keep up to 2 retired donors resident (the warm prefix
            # cache), so sharing works across waves and idle gaps
            prefix_cache_seqs=2,
        ))
        done = srv.run(make_requests(cfg.vocab_size))
        stats = srv.engine.serving_stats()
        name = "shared" if sharing else "unshared"
        print(f"[{name}] {len(done)} requests, 2 tenants, one 24-token "
              f"system prompt")
        print(f"  prefix hits       : {stats['prefix_hits_total']}")
        print(f"  pages shared      : {stats['prefix_shared_pages_total']}")
        print(f"  tokens saved      : "
              f"{stats['prefix_prefill_tokens_saved_total']} "
              f"(of {sum(len(r.prompt) for r in done)} prompt tokens)")
        print(f"  COW copies        : {stats['prefix_cow_copies_total']}")
        print(f"  prefill tokens    : "
              f"{stats['prefill_tokens_total']['incremental']}")
        srv.engine.flush_prefix_cache()              # release parked donors
        assert srv.kv.pages_allocated == srv.kv.pages_freed
        streams[sharing] = {r.request_id: tuple(r.tokens) for r in done}
        srv.close()

    print("streams byte-identical with and without sharing:",
          streams[True] == streams[False])


if __name__ == "__main__":
    main()
