"""SEE++ feature tour: policies, budgets, serverless tasks, artifacts,
and the two paper bug reproductions — in one script.

    PYTHONPATH=src python examples/sandbox_udf.py
"""

import jax
import jax.numpy as jnp

from repro.core import (
    AdmissionController, ArtifactRepository, BudgetExceeded,
    LegacyFilterPolicy, ModernEmulationPolicy, Sandbox, SandboxPool,
    SandboxViolation, ServerlessScheduler, TaskSpec, TenantQuota,
)
from repro.core.elf import build_prophet_like
from repro.core.loader import ImageLoader, SegfaultError
from repro.core.mm import MemoryManager, MMConfig


def main():
    # 1. legacy filtering vs modern emulation (paper §II vs §III)
    udf = lambda x: jax.lax.scan(lambda c, t: (c + jnp.tanh(t), c), 0.0, x)[0]
    try:
        Sandbox(policy=LegacyFilterPolicy()).run(udf, jnp.arange(8.0))
    except SandboxViolation as e:
        print("legacy filter:", e)
    r = Sandbox(policy=ModernEmulationPolicy()).run(udf, jnp.arange(8.0))
    print(f"modern sentry: value={float(r.value):.3f} flops={r.flops:.0f}")

    # 2. resource isolation
    try:
        Sandbox(flop_budget=100.0).run(
            lambda a, b: a @ b, jnp.ones((64, 64)), jnp.ones((64, 64)))
    except BudgetExceeded as e:
        print("budget:", e)

    # 3. serverless tasks (§V.A)
    sched = ServerlessScheduler(
        quotas={"tenant-a": TenantQuota(flop_budget_per_task=1e9)})
    t1 = sched.submit(TaskSpec("tenant-a", udf, (jnp.arange(4.0),)))
    sched.run_pending()
    print("task:", sched.record(t1).state)

    # 4. artifact repository (§V.B): no allowlist churn
    repo = ArtifactRepository(ModernEmulationPolicy())
    rep = repo.register_op("fancy", "1.0",
                           lambda x: jax.lax.erf(x).sum(), (jnp.ones(3),))
    print("artifact admitted:", rep.admitted, rep.artifact.digest)

    # 4b. unified admission: repeat submissions skip trace+verify, and
    # warm sandboxes come from the pool (the startup-latency story)
    ctl = AdmissionController()
    pool = SandboxPool(admission=ctl)
    sb = pool.checkout("tenant-a")
    cold = sb.run(udf, jnp.arange(8.0))
    warm = sb.run(udf, jnp.arange(8.0))
    pool.checkin(sb)
    print(f"admission: cold cache_hit={cold.cache_hit} "
          f"warm cache_hit={warm.cache_hit} stats={ctl.stats()}")

    # 5. §IV.A: the VMA blow-up and the fix
    for name, cfg in (("legacy", MMConfig.legacy()), ("modern", MMConfig.modern())):
        mm = MemoryManager(cfg)
        for _ in range(500):
            ar = mm.mmap(64 * 1024)
            mm.touch(ar.start, 64 * 1024)
        print(f"§IV.A {name}: host VMAs = {mm.host_vma_count()}")

    # 6. §IV.B: the prophet segfault and the fix
    blob = build_prophet_like()
    try:
        ImageLoader("legacy").load(blob)
    except SegfaultError as e:
        print("§IV.B legacy:", e)
    ImageLoader("linux").load(blob)
    print("§IV.B linux semantics: loads cleanly")


if __name__ == "__main__":
    main()
