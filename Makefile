PYTHON ?= python

.PHONY: test bench dev-deps

# tier-1 verification: the exact command CI and ROADMAP.md reference
test:
	PYTHONPATH=src $(PYTHON) -m pytest -x -q

bench:
	PYTHONPATH=src:. $(PYTHON) benchmarks/run.py

dev-deps:
	$(PYTHON) -m pip install -r requirements-dev.txt
