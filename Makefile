PYTHON ?= python

.PHONY: test lint bench bench-smoke bench-trend chaos serve-chaos \
	orch-chaos examples ci dev-deps

# tier-1 verification: the exact command CI and ROADMAP.md reference
# (includes the scheduler chaos suite at its fixed default seed window)
test:
	PYTHONPATH=src $(PYTHON) -m pytest -x -q

# chaos sweep over a rotating seed window (a new 200-seed slice each
# day), exploring interleavings CI's fixed window never visits; a
# failure prints its replay seed — rerun it alone with
# CHAOS_SEED_START=<seed> CHAOS_SEED_COUNT=1
#
# PYTEST_FLAGS passes extra pytest args through (the nightly workflow
# adds --junitxml=... for its artifacts)
chaos:
	CHAOS_SEED_START=$$(( ($$(date +%s) / 86400 % 5000) * 200 )) \
	CHAOS_SEED_COUNT=200 \
	PYTHONPATH=src $(PYTHON) -m pytest -x -q $(PYTEST_FLAGS) \
		tests/test_scheduler_chaos.py

# serving-plane chaos sweep (batch kills + KV-arena poison, plus the
# mesh-fault plane: replica kills + silent mesh-member death) over
# rotating seed windows; CI runs the fixed windows (serve 0..59, mesh
# 0..19) inside tier-1.  Replay one failure with
# CHAOS_SERVE_SEED_START=<seed> CHAOS_SERVE_SEED_COUNT=1 (or the
# MESH_CHAOS_SEED_* pair for the mesh sweep)
serve-chaos:
	CHAOS_SERVE_SEED_START=$$(( ($$(date +%s) / 86400 % 5000) * 120 )) \
	CHAOS_SERVE_SEED_COUNT=120 \
	MESH_CHAOS_SEED_START=$$(( ($$(date +%s) / 86400 % 5000) * 40 )) \
	MESH_CHAOS_SEED_COUNT=40 \
	PYTHONPATH=src $(PYTHON) -m pytest -x -q $(PYTEST_FLAGS) \
		tests/test_serving_chaos.py

# orchestration chaos sweep (mixed workloads + node kills + forced
# scale events on the shared pool) over a rotating seed window; CI runs
# the fixed window (0..29) inside tier-1.  Replay one failure with
# ORCH_CHAOS_SEED_START=<seed> ORCH_CHAOS_SEED_COUNT=1
orch-chaos:
	ORCH_CHAOS_SEED_START=$$(( ($$(date +%s) / 86400 % 5000) * 60 )) \
	ORCH_CHAOS_SEED_COUNT=60 \
	PYTHONPATH=src $(PYTHON) -m pytest -x -q $(PYTEST_FLAGS) \
		tests/test_orchestrator_chaos.py

# every demo in examples/ runs headless, end to end (the CI examples
# job runs this same loop) — a new example file is covered by the
# wildcard automatically, and the first failure stops the run
examples:
	@set -e; for ex in examples/*.py; do \
		echo "== $$ex"; PYTHONPATH=src $(PYTHON) $$ex; \
	done

# same invocation as the CI lint job (config in ruff.toml)
lint:
	ruff check src tests benchmarks

bench:
	PYTHONPATH=src:. $(PYTHON) benchmarks/run.py

# the CI bench-smoke job at identical tiny sizes; writes BENCH_*.json
bench-smoke:
	PYTHONPATH=src $(PYTHON) benchmarks/admission_bench.py \
		--cold-iters 15 --warm-reps 2000 --pool-reps 50 --size 64 \
		--json-out BENCH_admission.json
	PYTHONPATH=src $(PYTHON) benchmarks/pool_bench.py \
		--requests 200 --watermark 4 --repeats 5 \
		--json-out BENCH_pool.json
	PYTHONPATH=src $(PYTHON) benchmarks/scheduler_bench.py \
		--tasks 40 --workers 4 --json-out BENCH_scheduler.json
	PYTHONPATH=src $(PYTHON) benchmarks/serve_bench.py \
		--requests 12 --json-out BENCH_serve.json
	PYTHONPATH=src $(PYTHON) benchmarks/prefix_bench.py \
		--requests 8 --json-out BENCH_prefix.json
	PYTHONPATH=src $(PYTHON) benchmarks/orchestrator_bench.py \
		--json-out BENCH_orchestrator.json

# the CI trend check, locally: diff BENCH_*.json against .bench-baseline/
# (seeded on the first run) and fail on a >30% regression
bench-trend: bench-smoke
	PYTHONPATH=src $(PYTHON) benchmarks/trend_check.py \
		--old-dir .bench-baseline --new-dir . \
		--tolerance 0.30 --update-baseline

# everything the CI pipeline runs, locally — including the trend gate
# (bench-trend wraps bench-smoke, so a green `make ci` predicts a green
# pipeline instead of silently skipping the regression check)
ci: lint test bench-trend examples

dev-deps:
	$(PYTHON) -m pip install -r requirements-dev.txt
	$(PYTHON) -m pip install "ruff>=0.4"
