"""Aggregate dry-run artifacts into the §Roofline table.

Reads ``experiments/dryrun/*.json`` (written by ``repro.launch.dryrun``)
and emits the per-(arch × shape × mesh) roofline table: the three terms in
seconds, the dominant bottleneck, MODEL_FLOPS/HLO_FLOPS, and per-device
memory.  Markdown output goes to ``experiments/roofline.md`` for inclusion
in EXPERIMENTS.md.
"""

from __future__ import annotations

import json
from pathlib import Path
from typing import Dict, List, Optional

DRYRUN_DIR = Path("experiments/dryrun")


def load_cells(tag: Optional[str] = None) -> List[dict]:
    cells = []
    for f in sorted(DRYRUN_DIR.glob("*.json")):
        parts = f.stem.split("__")
        cell_tag = parts[3] if len(parts) > 3 else None
        if cell_tag != tag:
            continue
        cells.append(json.loads(f.read_text()))
    return cells


def _fmt_s(x: float) -> str:
    if x >= 1.0:
        return f"{x:7.2f}s "
    return f"{x*1e3:7.1f}ms"


def table(cells: List[dict], *, mesh: str = "16x16") -> str:
    rows = [c for c in cells if c["mesh"] == mesh]
    rows.sort(key=lambda c: (c["arch"], c["shape"]))
    out = [
        "| arch | shape | compute | memory | collective | dominant | "
        "useful-FLOP | HBM/dev |",
        "|---|---|---|---|---|---|---|---|",
    ]
    for c in rows:
        r = c["roofline"]
        mem = c.get("memory") or {}
        hbm = (mem.get("argument_bytes") or 0) + (mem.get("temp_bytes") or 0)
        out.append(
            f"| {c['arch']} | {c['shape']} | {_fmt_s(r['compute_s'])} | "
            f"{_fmt_s(r['memory_s'])} | {_fmt_s(r['collective_s'])} | "
            f"{r['dominant']} | {min(r['useful_flop_ratio'], 9.99):.3f} | "
            f"{hbm/2**30:.1f}GiB |"
        )
    return "\n".join(out)


def summary(cells: List[dict]) -> Dict[str, object]:
    single = [c for c in cells if c["mesh"] == "16x16"]
    multi = [c for c in cells if c["mesh"] == "2x16x16"]
    dominated: Dict[str, int] = {}
    for c in single:
        d = c["roofline"]["dominant"]
        dominated[d] = dominated.get(d, 0) + 1
    worst = sorted(
        (c for c in single if c["kind"] == "train"),
        key=lambda c: c["roofline"]["useful_flop_ratio"],
    )
    most_coll = sorted(
        single,
        key=lambda c: -c["roofline"]["collective_s"]
        / max(c["roofline"]["step_s_lower_bound"], 1e-12),
    )
    return {
        "cells_single": len(single),
        "cells_multi": len(multi),
        "dominant_histogram": dominated,
        "worst_useful": [(c["arch"], c["shape"],
                          round(c["roofline"]["useful_flop_ratio"], 3))
                         for c in worst[:3]],
        "most_collective_bound": [
            (c["arch"], c["shape"],
             round(c["roofline"]["collective_s"]
                   / max(c["roofline"]["step_s_lower_bound"], 1e-12), 3))
            for c in most_coll[:3]
        ],
    }


def main() -> Dict[str, object]:
    cells = load_cells()
    md = ["## Roofline — single-pod (16×16, 256 chips, v5e constants)", "",
          table(cells, mesh="16x16"), "",
          "## Multi-pod pass (2×16×16, 512 chips)", "",
          table(cells, mesh="2x16x16")]
    Path("experiments/roofline.md").write_text("\n".join(md))
    s = summary(cells)
    print(f"# roofline: {s['cells_single']} single-pod + "
          f"{s['cells_multi']} multi-pod cells aggregated")
    print(f"  dominant-term histogram: {s['dominant_histogram']}")
    print(f"  worst useful-FLOP ratios: {s['worst_useful']}")
    print(f"  most collective-bound: {s['most_collective_bound']}")
    print("  table -> experiments/roofline.md")
    return s


if __name__ == "__main__":
    main()
