"""Fig. 3 analogue: TPCx-BB-style query suite, legacy vs modern sandbox.

Ten DataFrame/ML queries (filter-aggregate, groupby, join, window, top-k,
quantiles, featurize, linear-regression step, k-means step, UDF pipeline)
run through ``Sandbox.run`` under the legacy filter policy and the modern
Sentry policy.  Latency includes admission (per-primitive policy checks —
the legacy path's allowlist lookups are its runtime analogue of seccomp
filtering) plus compiled execution.  The paper reports the top-10 query
latencies and a 1.5% overall improvement; we report the same comparison.
"""

from __future__ import annotations

import time
from typing import Callable, Dict, List, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import LegacyFilterPolicy, Sandbox

N = 400_000
KEYS = 512


def _data(seed=0):
    rng = np.random.default_rng(seed)
    return {
        "val": jnp.asarray(rng.standard_normal(N), jnp.float32),
        "key": jnp.asarray(rng.integers(0, KEYS, N), jnp.int32),
        "price": jnp.asarray(rng.gamma(2.0, 10.0, N), jnp.float32),
        "dim_val": jnp.asarray(rng.standard_normal(KEYS), jnp.float32),
        "x": jnp.asarray(rng.standard_normal((4096, 64)), jnp.float32),
        "y": jnp.asarray(rng.standard_normal(4096), jnp.float32),
        "w": jnp.asarray(rng.standard_normal(64), jnp.float32) * 0.01,
        "cent": jnp.asarray(rng.standard_normal((8, 64)), jnp.float32),
    }


def q1_filter_agg(d):
    m = d["price"] > 15.0
    return jnp.sum(jnp.where(m, d["val"], 0.0)) / jnp.maximum(jnp.sum(m), 1)


def q2_groupby_mean(d):
    sums = jnp.zeros(KEYS).at[d["key"]].add(d["val"])
    cnts = jnp.zeros(KEYS).at[d["key"]].add(1.0)
    return sums / jnp.maximum(cnts, 1.0)


def q3_join(d):
    return jnp.sum(d["val"] * d["dim_val"][d["key"]])


def q4_window(d):
    k = jnp.ones(64) / 64.0
    return jnp.convolve(d["price"][:65_536], k, mode="same").sum()


def q5_topk(d):
    v, i = jax.lax.top_k(d["price"], 100)
    return v.sum() + i.sum()


def q6_quantiles(d):
    s = jnp.sort(d["val"])
    idx = (jnp.asarray([0.01, 0.25, 0.5, 0.75, 0.99]) * (N - 1)).astype(int)
    return s[idx]


def q7_featurize(d):
    z = (d["val"] - d["val"].mean()) / (d["val"].std() + 1e-6)
    onehot = jax.nn.one_hot(d["key"][:8192] % 64, 64)
    return (onehot * z[:8192, None]).sum(0)


def q8_linreg_step(d):
    def loss(w):
        return jnp.mean(jnp.square(d["x"] @ w - d["y"]))
    g = jax.grad(loss)(d["w"])
    return d["w"] - 0.01 * g


def q9_kmeans_step(d):
    dist = jnp.sum(
        jnp.square(d["x"][:, None, :] - d["cent"][None]), axis=-1)
    assign = jnp.argmin(dist, axis=1)
    onehot = jax.nn.one_hot(assign, 8)
    new = (onehot.T @ d["x"]) / jnp.maximum(onehot.sum(0)[:, None], 1.0)
    return new


def q10_udf_pipeline(d):
    v = d["val"][:65_536]
    acc = jnp.zeros_like(v)
    for c in (0.5, -0.25, 0.125):
        acc = jnp.tanh(acc + c * v)
        v = v * 0.9
    return acc.sum()


QUERIES: List[Tuple[str, Callable]] = [
    ("q1_filter_agg", q1_filter_agg), ("q2_groupby_mean", q2_groupby_mean),
    ("q3_join", q3_join), ("q4_window", q4_window), ("q5_topk", q5_topk),
    ("q6_quantiles", q6_quantiles), ("q7_featurize", q7_featurize),
    ("q8_linreg_step", q8_linreg_step), ("q9_kmeans_step", q9_kmeans_step),
    ("q10_udf_pipeline", q10_udf_pipeline),
]


def _run_suite(sandbox: Sandbox, data, reps: int) -> Dict[str, float]:
    out = {}
    for name, fn in QUERIES:
        wrapped = lambda d, fn=fn: fn(d)
        wrapped.__name__ = name
        sandbox.run(wrapped, data)               # warmup (verify + compile)
        times = []
        for _ in range(reps):
            t0 = time.perf_counter()
            r = sandbox.run(wrapped, data)
            jax.block_until_ready(r.value)
            times.append(time.perf_counter() - t0)
        out[name] = sorted(times)[len(times) // 2]
    return out


def main(reps: int = 5) -> Dict[str, float]:
    data = _data()
    legacy = Sandbox(
        tenant="legacy",
        policy=LegacyFilterPolicy().extended(
            # the maintenance treadmill: these required manual additions
            "reduce_window_sum", "top_k", "erf", "scatter-add",
            "reduce_precision", "exp2", "log2", "sign", "atan2",
        ),
    )
    modern = Sandbox(tenant="modern")
    lt = _run_suite(legacy, data, reps)
    mt = _run_suite(modern, data, reps)
    print("# query_latency (TPCx-BB analogue): median seconds per query")
    print(f"  {'query':18s} {'legacy':>10s} {'modern':>10s} {'delta':>8s}")
    for name, _ in QUERIES:
        d = (lt[name] - mt[name]) / lt[name] * 100
        print(f"  {name:18s} {lt[name]*1e3:9.2f}ms {mt[name]*1e3:9.2f}ms "
              f"{d:+7.1f}%")
    total_l, total_m = sum(lt.values()), sum(mt.values())
    overall = (total_l - total_m) / total_l * 100
    print(f"  {'TOTAL':18s} {total_l*1e3:9.2f}ms {total_m*1e3:9.2f}ms "
          f"{overall:+7.1f}%   (paper: +1.5%)")
    return {"overall_improvement_pct": overall,
            **{f"legacy_{k}": v for k, v in lt.items()},
            **{f"modern_{k}": v for k, v in mt.items()}}


if __name__ == "__main__":
    main()
