"""Decode-latency protection under mixed workloads: orchestrated vs FIFO.

The unified orchestrator's claim is that co-locating latency-sensitive
decode with throughput batch work on one pool does *not* cost decode its
latency — because decode gets a priority lane, the largest DRR weight
and preemption rights.  This bench runs the **same virtual-clock
workload** (a staggered stream of decode requests + a bag of cooperative
batch jobs on a 2-worker pool) under two placement policies:

* **orchestrated** — the default :class:`OrchestratorConfig`: decode at
  priority 0 / weight 4 with preemption rights over batch;
* **naive FIFO mixing** — every class at the same priority and weight,
  preemption disabled: decode steps queue behind whatever batch work
  got there first.

Everything runs on a seeded :class:`~repro.core.sim.SimExecutor`, so
both runs see byte-identical workloads and the reported latencies are
virtual-clock deterministic — the protection ratio is a pure scheduling
measure, immune to machine load.  Reported:

* ``decode_p50_protection_x`` / ``decode_p95_protection_x`` — naive p50
  (p95) over orchestrated p50 (p95); higher is better, must be > 1;
* ``batch_makespan_cost_x`` — orchestrated batch makespan over naive;
  the (bounded) price batch pays for decode's lane.

``--json-out`` writes ``BENCH_orchestrator.json`` for the CI trend check.
"""

from __future__ import annotations

import argparse
import json
from typing import Dict, List, Optional

import jax
import numpy as np

from repro.configs import get_reduced
from repro.core import ServerlessScheduler, SimExecutor
from repro.core.tasks import checkpoint
from repro.models import build_model
from repro.runtime import Request, ServingEngine
from repro.runtime.orchestrator import (OrchestratorConfig,
                                        WorkloadOrchestrator)
from repro.runtime.serve_loop import ServerConfig

N_REQUESTS = 12
N_JOBS = 6
JOB_SLEEPS = 10               # 10 x 10ms cooperative segments per job
STEP_TIME_S = 0.01            # virtual decode step latency
ARRIVAL_GAP_S = 0.02


def _build_engine(executor) -> ServingEngine:
    cfg = get_reduced("gemma2-9b")
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    engine = ServingEngine(
        model, params,
        ServerConfig(max_batch=3, max_seq=48, step_time_s=STEP_TIME_S),
        executor=executor,
    )
    return engine


def _requests(vocab: int) -> List[Request]:
    rng = np.random.default_rng(0)
    return [Request(
        prompt=rng.integers(0, vocab, (4,)).astype(np.int32),
        max_new_tokens=4,
        request_id=i,
    ) for i in range(N_REQUESTS)]


def run_policy(policy: str, *, seed: int = 7) -> Dict[str, float]:
    """One full mixed-workload drain under ``policy``; virtual-clock stats."""
    sim = SimExecutor(seed=seed)
    engine = _build_engine(sim)
    sched = ServerlessScheduler(workers=2, executor=sim)
    sched.start()
    if policy == "orchestrated":
        ocfg = OrchestratorConfig()
    else:                              # flat: one band, one weight, no rights
        ocfg = OrchestratorConfig(
            serving_priority=10, train_priority=10, batch_priority=10,
            serving_weight=1, train_weight=1, batch_weight=1,
            max_preemptions_per_job=0,
        )
    orch = WorkloadOrchestrator(sched, serving=engine, cfg=ocfg)

    reqs = _requests(engine.model.cfg.vocab_size)
    for i, r in enumerate(reqs):
        sim.call_at(0.01 + ARRIVAL_GAP_S * i, lambda r=r: engine.submit(r))

    def make_body():
        def body():
            for _ in range(JOB_SLEEPS):
                checkpoint()           # cooperative preemption point
                sim.sleep(STEP_TIME_S)
            return JOB_SLEEPS

        return body

    batch_done_at = {}
    jobs = [orch.submit_batch(make_body(), name=f"job{i}")
            for i in range(N_JOBS)]

    def watch_batch() -> None:
        for j in jobs:
            if j.state == "done" and j.job_id not in batch_done_at:
                batch_done_at[j.job_id] = sim.now()

    # explicit tick pump well past the workload horizon (the sim stops as
    # soon as everything is idle, so overshoot is free)
    for k in range(400):
        sim.call_at(0.005 * k + 0.002, orch.tick)
        sim.call_at(0.005 * k + 0.003, watch_batch)
    sim.run()
    orch.tick()
    watch_batch()
    sched.drain(timeout=120)
    sim.run()

    assert all(r.done and r.error is None for r in reqs), policy
    assert all(j.state == "done" for j in jobs), policy
    lat = sorted(r.latency_s for r in reqs)
    stats = orch.orchestrator_stats()
    sched.shutdown()
    return {
        "decode_p50_s": lat[len(lat) // 2],
        "decode_p95_s": lat[min(len(lat) - 1, int(len(lat) * 0.95))],
        "decode_mean_s": sum(lat) / len(lat),
        "batch_makespan_s": max(batch_done_at.values()),
        "preemptions": float(stats["preemptions_total"]),
    }


def main(json_out: Optional[str] = None) -> Dict[str, float]:
    orch = run_policy("orchestrated")
    naive = run_policy("naive")

    p50_x = naive["decode_p50_s"] / orch["decode_p50_s"]
    p95_x = naive["decode_p95_s"] / orch["decode_p95_s"]
    batch_cost_x = orch["batch_makespan_s"] / naive["batch_makespan_s"]

    print("# orchestrator_bench")
    print(f"  workload: {N_REQUESTS} decode requests ({ARRIVAL_GAP_S*1e3:.0f}ms"
          f" apart) + {N_JOBS} batch jobs ({JOB_SLEEPS}x{STEP_TIME_S*1e3:.0f}ms)"
          " on 2 workers, virtual clock")
    print(f"  {'policy':14s} {'p50':>8s} {'p95':>8s} {'mean':>8s}"
          f" {'batch_mkspan':>13s} {'preempts':>9s}")
    for name, r in (("orchestrated", orch), ("naive-fifo", naive)):
        print(f"  {name:14s} {r['decode_p50_s']*1e3:7.1f}ms"
              f" {r['decode_p95_s']*1e3:7.1f}ms"
              f" {r['decode_mean_s']*1e3:7.1f}ms"
              f" {r['batch_makespan_s']*1e3:12.1f}ms"
              f" {r['preemptions']:9.0f}")
    print(f"  decode p50 protection: {p50_x:.2f}x  (p95 {p95_x:.2f}x;"
          f" batch makespan cost {batch_cost_x:.2f}x)")

    # the headline guarantee: class-aware placement strictly beats flat
    # mixing on decode latency, and batch still finishes (bounded cost)
    assert p50_x > 1.0, (orch, naive)
    assert batch_cost_x < 5.0, (orch, naive)

    result = {
        "decode_p50_protection_x": p50_x,
        "decode_p95_protection_x": p95_x,
        "batch_makespan_cost_x": batch_cost_x,
        "orchestrated_decode_p50_ms": orch["decode_p50_s"] * 1e3,
        "naive_decode_p50_ms": naive["decode_p50_s"] * 1e3,
        "orchestrated_preemptions": orch["preemptions"],
    }
    if json_out:
        with open(json_out, "w") as f:
            json.dump(result, f, indent=2)
        print(f"  wrote {json_out}")
    return result


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--json-out", default=None,
                    help="write a BENCH_orchestrator.json artifact")
    args = ap.parse_args()
    main(json_out=args.json_out)
