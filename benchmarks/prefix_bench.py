"""Cross-tenant prefix sharing: prefill tokens/request vs prompt overlap.

Overlapping prompts (a shared system prompt, a few-shot header) are the
serving plane's analogue of SEE++'s redundant per-tenant sandbox setup:
without sharing, every request re-prefills the identical header.  This
bench drives the same paged :class:`ServingEngine` over a workload whose
prompts overlap by a swept ratio — shared vs unshared
(``ServerConfig.prefix_sharing``) — and reports prefill tokens/request
for each, after warming the prefix cache with one request per header
(``prefix_cache_seqs``, the warm-cache deployment shape).

Two hard gates run on every invocation:

* at >= 75% overlap the shared run prefills **>= 2x fewer** tokens per
  request than the unshared run (the tentpole's acceptance floor), and
* every request's token stream is **byte-identical** across the two
  runs — the suffix prefill attends through the donor's resident K/V
  rows and must reproduce the full prefill bit-for-bit (bf16 rounds the
  same both ways), or sharing is silently serving different tokens.

``--json-out`` writes ``BENCH_prefix.json``; the CI trend check tracks
``prefix_prefill_tokens_saved_x``.
"""

from __future__ import annotations

import argparse
import json
import time
from typing import Dict, List, Optional

import jax
import numpy as np

from repro.configs import get_reduced
from repro.models import build_model
from repro.runtime import Request, ServingEngine
from repro.runtime.serve_loop import ServerConfig

OVERLAPS = (0.0, 0.5, 0.75)


def _requests(n: int, prompt_len: int, overlap: float, new_tokens: int,
              vocab: int, tail_seed: int = 11) -> List[Request]:
    """n requests whose prompts open with a common ``overlap`` fraction.

    The header is fixed across requests (two tenants alternate, like two
    products sharing one system prompt); the tail is per-request random.
    Deterministic: same args, same workload.  ``tail_seed`` keys the
    tails only — the warm request uses its own so it never duplicates a
    measured prompt outright (a full-prompt match would fake a hit even
    at overlap 0).
    """
    header = np.random.default_rng(7).integers(
        0, vocab, (int(prompt_len * overlap),)
    )
    rng = np.random.default_rng(tail_seed)
    reqs = []
    for i in range(n):
        tail = rng.integers(0, vocab, (prompt_len - header.size,))
        reqs.append(Request(
            prompt=np.concatenate([header, tail]).astype(np.int32),
            max_new_tokens=new_tokens,
            request_id=i,
            tenant=("alice", "bob")[i % 2],
        ))
    return reqs


def _run(arch: str, *, sharing: bool, requests: int, prompt_len: int,
         overlap: float, new_tokens: int, max_batch: int,
         max_seq: int) -> Dict[str, object]:
    cfg = get_reduced(arch)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    engine = ServingEngine(
        model, params,
        ServerConfig(max_batch=max_batch, max_seq=max_seq,
                     kv_mode="paged", prefix_sharing=sharing,
                     prefix_cache_seqs=2),
    )
    assert engine.kv_mode == "paged"

    # warm phase: one request carries the header through prefill and is
    # parked as a prefix donor — plus it compiles the jit variants
    # outside the timed window.  The unshared run warms identically so
    # the prefill-token subtraction is apples to apples
    warm = _requests(1, prompt_len, overlap, new_tokens, cfg.vocab_size,
                     tail_seed=12)
    warm[0].request_id = 10_000
    engine.submit(warm[0])
    engine.drain()
    warm_tokens = engine.serving_stats()["prefill_tokens_total"]["incremental"]

    reqs = _requests(requests, prompt_len, overlap, new_tokens,
                     cfg.vocab_size)
    t0 = time.perf_counter()
    for r in reqs:
        engine.submit(r)
    engine.drain()
    wall = time.perf_counter() - t0

    assert all(r.error is None for r in reqs)
    engine.flush_prefix_cache()
    assert engine.kv.live_pages() == 0
    assert engine.kv.pages_allocated == engine.kv.pages_freed
    stats = engine.serving_stats()
    return {
        "streams": {r.request_id: tuple(r.tokens) for r in reqs},
        "prefill_tokens": stats["prefill_tokens_total"]["incremental"]
        - warm_tokens,
        "prefix_hits": stats["prefix_hits_total"],
        "cow_copies": stats["prefix_cow_copies_total"],
        "tokens_saved": stats["prefix_prefill_tokens_saved_total"],
        "wall_s": wall,
    }


def run_overlap_sweep(arch: str, *, requests: int, prompt_len: int,
                      new_tokens: int, max_batch: int,
                      max_seq: int) -> List[Dict[str, float]]:
    rows = []
    for overlap in OVERLAPS:
        common = dict(requests=requests, prompt_len=prompt_len,
                      overlap=overlap, new_tokens=new_tokens,
                      max_batch=max_batch, max_seq=max_seq)
        shared = _run(arch, sharing=True, **common)
        unshared = _run(arch, sharing=False, **common)
        assert shared["streams"] == unshared["streams"], (
            f"token streams diverged at overlap={overlap}: sharing must "
            "be invisible to the decoded output"
        )
        rows.append({
            "overlap": overlap,
            "shared_prefill_tokens_per_req":
                shared["prefill_tokens"] / requests,
            "unshared_prefill_tokens_per_req":
                unshared["prefill_tokens"] / requests,
            "reduction_x":
                unshared["prefill_tokens"]
                / max(shared["prefill_tokens"], 1),
            "prefix_hits": shared["prefix_hits"],
            "cow_copies": shared["cow_copies"],
            "tokens_saved": shared["tokens_saved"],
        })
    return rows


def main(
    arch: str = "qwen2.5-32b",
    requests: int = 8,
    prompt_len: int = 32,
    new_tokens: int = 4,
    max_batch: int = 2,
    max_seq: int = 64,
    json_out: Optional[str] = None,
) -> Dict[str, object]:
    rows = run_overlap_sweep(
        arch, requests=requests, prompt_len=prompt_len,
        new_tokens=new_tokens, max_batch=max_batch, max_seq=max_seq,
    )
    headline = rows[-1]["reduction_x"]     # the >=75%-overlap cell
    # acceptance floor (hard assert, like serve_bench's speedup gates):
    # a broken radix lookup or an over-eager COW collapses this toward
    # 1x long before the trend check would notice a relative drift
    assert headline >= 2.0, (
        f"prefix sharing saved only {headline:.2f}x prefill tokens at "
        f"{OVERLAPS[-1]:.0%} overlap"
    )
    assert rows[-1]["prefix_hits"] == requests, rows[-1]
    assert rows[0]["prefix_hits"] == 0, rows[0]

    print("# prefix_bench")
    print(f"  arch={arch} requests={requests} prompt={prompt_len} "
          f"new={new_tokens} batch={max_batch}")
    for row in rows:
        print(f"  overlap={row['overlap']:4.0%} : "
              f"unshared {row['unshared_prefill_tokens_per_req']:6.1f} "
              f"tok/req, shared {row['shared_prefill_tokens_per_req']:6.1f} "
              f"tok/req -> {row['reduction_x']:.2f}x "
              f"(hits={row['prefix_hits']} cow={row['cow_copies']})")
    print(f"  prefill reduction   : {headline:.2f}x at "
          f"{OVERLAPS[-1]:.0%} overlap, streams byte-identical")

    result = {
        "arch": arch,
        "requests": requests,
        "prompt_len": prompt_len,
        "overlap_sweep": rows,
        "prefix_prefill_tokens_saved_x": headline,
    }
    if json_out:
        with open(json_out, "w") as fh:
            json.dump(result, fh, indent=2, sort_keys=True)
        print(f"  wrote {json_out}")
    return result


if __name__ == "__main__":
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--arch", default="qwen2.5-32b")
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--new-tokens", type=int, default=4)
    ap.add_argument("--max-batch", type=int, default=2)
    ap.add_argument("--max-seq", type=int, default=64)
    ap.add_argument("--json-out", default=None)
    a = ap.parse_args()
    main(arch=a.arch, requests=a.requests, prompt_len=a.prompt_len,
         new_tokens=a.new_tokens, max_batch=a.max_batch, max_seq=a.max_seq,
         json_out=a.json_out)
