"""Benchmark harness: one entry per paper table/figure + the roofline.

Prints ``name,value,derived`` CSV rows after each bench's own report.
"""

from __future__ import annotations

from repro.launch.mesh import simulate_host_devices

# the serve bench's tensor-parallel sweep needs a simulated device mesh,
# and XLA freezes the host device count at the first computation — so
# the split must happen before ANY bench touches a device
simulate_host_devices(4)


def main() -> None:
    from benchmarks import (
        admission_bench,
        loader_bench,
        orchestrator_bench,
        pool_bench,
        prefix_bench,
        query_latency,
        roofline,
        scheduler_bench,
        sentry_overhead,
        serve_bench,
        vma_bench,
    )

    rows = []

    print("=" * 72)
    vma = vma_bench.main()
    rows += [
        ("vma_blowup_legacy_vs_native_x", vma["blowup_x"], "paper:>500x"),
        ("vma_reduction_fix_x", vma["reduction_clean_x"], "paper:182x"),
        ("vma_legacy_crash", vma["legacy_crash"], "paper:crash@65530"),
    ]

    print("=" * 72)
    q = query_latency.main()
    rows.append(
        ("query_suite_improvement_pct", q["overall_improvement_pct"],
         "paper:+1.5pct")
    )

    print("=" * 72)
    ld = loader_bench.main()
    rows += [
        ("loader_legacy_success_pct", ld["legacy_success_pct"],
         "paper:prophet-segfault"),
        ("loader_linux_success_pct", ld["linux_success_pct"], "paper:100"),
    ]

    print("=" * 72)
    so = sentry_overhead.main()
    rows += [
        ("sentry_steady_state_overhead_pct",
         so["steady_state_overhead_pct"], "target:~0"),
        ("sentry_emulation_slowdown_x", so["emulation_slowdown_x"],
         "ptrace-mode analogue"),
    ]

    print("=" * 72)
    ab = admission_bench.main()
    rows += [
        ("admission_warm_speedup_x", ab["warm_speedup_x"], "target:>=10x"),
        ("pool_checkout_speedup_x", ab["pool_checkout_speedup_x"],
         "warm-sandbox startup hiding"),
    ]

    print("=" * 72)
    pb = pool_bench.main()
    rows += [
        ("pool_refill_warm_speedup_x", pb["warm_speedup_x"], "target:>=5x"),
        ("pool_refill_cold_checkouts", pb["warm_cold_checkout_total"],
         "steady-state target:0"),
    ]

    print("=" * 72)
    sb = scheduler_bench.main()
    rows += [
        ("scheduler_concurrent_speedup_x", sb["speedup_x"], "target:>=2x"),
        ("scheduler_steal_speedup_x", sb["steal_speedup_x"],
         "skewed tenant, target:>=2x"),
        ("scheduler_sim_deterministic", float(sb["sim_deterministic"]),
         "3 same-seed runs byte-identical"),
    ]

    print("=" * 72)
    sv = serve_bench.main()
    rows += [
        ("serve_incremental_speedup_x", sv["incremental_speedup_x"],
         "skewed admit/retire, target:>=2x"),
        ("serve_prefill_reduction_x", sv["prefill_reduction_x"],
         "prefill tokens avoided vs rebatching"),
        ("serve_incremental_tokens_per_s", sv["incremental_tokens_per_s"],
         "reduced-model CPU decode"),
        ("serve_paged_speedup_x", sv["paged_speedup_x"],
         "paged vs dense KV at the largest (slots, max_seq) cell"),
        ("serve_chunk_stall_reduction_x", sv["chunk_stall_reduction_x"],
         "p99 inter-token stall, chunked vs monolithic long-prompt "
         "admit, target:>=3x"),
        ("serve_shard_speedup_x", sv["shard_speedup_x"],
         "mesh-4 vs mesh-1 TP decode; simulated shards share one core"),
    ]

    print("=" * 72)
    ob = orchestrator_bench.main()
    rows += [
        ("orchestrator_decode_p50_protection_x",
         ob["decode_p50_protection_x"],
         "class-aware vs naive FIFO mixing, target:>1x"),
        ("orchestrator_batch_makespan_cost_x",
         ob["batch_makespan_cost_x"], "batch's bounded price, target:<5x"),
    ]

    print("=" * 72)
    pfx = prefix_bench.main()
    rows.append(
        ("serve_prefix_tokens_saved_x",
         pfx["prefix_prefill_tokens_saved_x"],
         "shared vs unshared prefill at 75% prompt overlap, target:>=2x")
    )

    print("=" * 72)
    try:
        rf = roofline.main()
        hist = rf["dominant_histogram"]
        for term, count in sorted(hist.items()):
            rows.append((f"roofline_cells_dominated_by_{term}", count,
                         f"of {rf['cells_single']}"))
    except Exception as e:  # dry-run artifacts absent
        print(f"  roofline skipped: {e}")

    print("=" * 72)
    print("name,value,derived")
    for name, value, derived in rows:
        print(f"{name},{value:.4g},{derived}")


if __name__ == "__main__":
    main()
