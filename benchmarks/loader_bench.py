"""Paper §IV.B benchmark: ELF-compat suite under both loader semantics.

A corpus of SELF artifacts covering the compatibility surface: ordinary
binaries (memsz == filesz), zero-fill tails (memsz > filesz), and
prophet-class binaries (sections outside LOAD segments but inside the
page-aligned extension), plus real model checkpoints.  Reports the load
success rate and throughput under ``legacy`` vs ``linux`` semantics.
"""

from __future__ import annotations

import time
from typing import Dict, List, Tuple

import numpy as np

from repro.checkpoint import save_tree
from repro.core.elf import SELFWriter, build_prophet_like
from repro.core.loader import ImageLoader, SegfaultError


def _plain(n=5) -> List[Tuple[str, bytes]]:
    out = []
    for i in range(n):
        w = SELFWriter()
        data = bytes((i + j) % 251 for j in range(3000 + i * 500))
        ph = w.add_segment(data)
        w.add_section("text", 1, ph.p_vaddr, data)
        out.append((f"plain_{i}", w.finish()))
    return out


def _bss(n=5) -> List[Tuple[str, bytes]]:
    out = []
    for i in range(n):
        w = SELFWriter()
        data = bytes(range(1, 200 + i))
        ph = w.add_segment(data, memsz=len(data) + 300)
        w.add_section("text", 1, ph.p_vaddr, data)
        out.append((f"bss_{i}", w.finish()))
    return out


def _prophet(n=5) -> List[Tuple[str, bytes]]:
    return [
        (f"prophet_{i}", build_prophet_like(payload=bytes([i]) * (1000 + i)))
        for i in range(n)
    ]


def _checkpoints(n=3) -> List[Tuple[str, bytes]]:
    rng = np.random.default_rng(0)
    out = []
    for i in range(n):
        tree = {
            "w": rng.standard_normal((64, 70 + i)).astype(np.float32),
            "b": rng.standard_normal((33,)).astype(np.float32),
        }
        out.append((f"ckpt_{i}", save_tree(tree, step=i)))
    return out


def main() -> Dict[str, float]:
    corpus = _plain() + _bss() + _prophet() + _checkpoints()
    results = {}
    print("# loader_bench: SELF compat suite "
          f"({len(corpus)} artifacts: plain/bss/prophet-class/checkpoints)")
    for semantics in ("legacy", "linux"):
        loader = ImageLoader(semantics)
        ok, fail, t0 = 0, [], time.perf_counter()
        for name, blob in corpus:
            try:
                loader.load(blob, verify=True)
                ok += 1
            except SegfaultError:
                fail.append(name)
        dt = time.perf_counter() - t0
        rate = ok / len(corpus) * 100
        results[f"{semantics}_success_pct"] = rate
        results[f"{semantics}_secs"] = dt
        failing = f"  failing: {', '.join(fail)}" if fail else ""
        print(f"  {semantics:7s} success {ok}/{len(corpus)} ({rate:.0f}%) "
              f"in {dt*1e3:.1f}ms{failing}")
    print("  paper: prophet-class binaries segfault under legacy semantics "
          "and load under the fix.")
    return results


if __name__ == "__main__":
    main()
