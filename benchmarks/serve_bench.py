"""Incremental-prefill serving engine vs the rebatching baseline.

The old serving loop re-prefilled the *whole* batch on every admit and
retire — O(active · steps) prefill work (plus a fresh shape, hence a
fresh XLA compile, per wave).  The engine's incremental mode prefills
exactly the admitted sequence and writes it into its slot, leaving live
slots untouched.

This bench drives both modes of the same :class:`ServingEngine` over a
**skewed admit/retire workload** — a few long-lived sequences pin their
slots while a stream of short requests churns through the rest, the
pattern that maximizes re-prefill waste — and reports decoded tokens/s.
Target: **>= 2x** for the incremental engine.  Also reported: prefill
tokens pushed by each mode (the work the tentpole deletes), and a
3-run same-seed SimExecutor determinism check on the engine trace.

The **paged sweep** then A/Bs ``kv_mode="paged"`` against ``"dense"``
over growing (active slots x max_seq) cells with *short* live sequences
— the serving regime paged KV exists for: the dense path drags a
(B, max_seq) reservation through every decode step (attention over the
full reservation plus an O(max_seq) cache scatter), while the paged
path's cost follows the pages actually allocated.  The headline
``paged_speedup_x`` is the largest cell's ratio, and the cell series
must show the gap growing.

``--json-out`` writes ``BENCH_serve.json`` for the CI trend check.
"""

from __future__ import annotations

import argparse
import dataclasses
import hashlib
import json
import time
from typing import Dict, List, Optional

from repro.launch.mesh import make_serving_mesh, simulate_host_devices

# before the first computation: split the host CPU into 4 simulated XLA
# devices so the shard sweep has a mesh to run on (a no-op if XLA_FLAGS
# already pins a device count — e.g. under the test conftest)
simulate_host_devices(4)

import jax  # noqa: E402
import numpy as np  # noqa: E402

from repro.configs import get_reduced  # noqa: E402
from repro.models import build_model  # noqa: E402
from repro.runtime import Request, ServingEngine  # noqa: E402
from repro.runtime.serve_loop import ServerConfig  # noqa: E402


def _requests(n: int, prompt_len: int, new_tokens: int, long_every: int,
              long_tokens: int, vocab: int) -> List[Request]:
    """Deterministic skewed workload: mostly short churn, a few pinners."""
    rng = np.random.default_rng(0)
    reqs = []
    for i in range(n):
        is_long = long_every > 0 and i % long_every == 0
        reqs.append(Request(
            prompt=rng.integers(0, vocab, (prompt_len,)).astype(np.int32),
            max_new_tokens=long_tokens if is_long else new_tokens,
            request_id=i,
        ))
    return reqs


def _build_engine(arch: str, *, max_batch: int, max_seq: int,
                  incremental: bool, kv_mode: str = "dense",
                  kv_pool_pages=None, executor=None,
                  prefill_chunk_tokens: int = 0):
    cfg = get_reduced(arch)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    engine = ServingEngine(
        model, params,
        ServerConfig(max_batch=max_batch, max_seq=max_seq,
                     incremental=incremental, kv_mode=kv_mode,
                     kv_pool_pages=kv_pool_pages,
                     prefill_chunk_tokens=prefill_chunk_tokens),
        executor=executor,
    )
    return engine, cfg


def run_mode(arch: str, *, incremental: bool, requests: int, prompt_len: int,
             new_tokens: int, long_every: int, long_tokens: int,
             max_batch: int, max_seq: int) -> Dict[str, float]:
    engine, cfg = _build_engine(
        arch, max_batch=max_batch, max_seq=max_seq, incremental=incremental,
    )
    # warmup outside the timed window: decode-jit compile + first prefill
    for r in _requests(max_batch, prompt_len, 2, 0, 2, cfg.vocab_size):
        r.request_id += 10_000
        engine.submit(r)
    engine.drain()
    warm_stats = engine.serving_stats()

    reqs = _requests(requests, prompt_len, new_tokens, long_every,
                     long_tokens, cfg.vocab_size)
    t0 = time.perf_counter()
    for r in reqs:
        engine.submit(r)
    engine.drain()
    wall = time.perf_counter() - t0

    assert all(r.error is None for r in reqs)
    leaked = engine.kv.seq_lens()
    assert leaked.size == 0 and engine.kv.total_runs() == 0
    tokens = sum(len(r.tokens) for r in reqs)
    stats = engine.serving_stats()
    prefill_tokens = {
        mode: stats["prefill_tokens_total"][mode]
        - warm_stats["prefill_tokens_total"][mode]
        for mode in ("incremental", "full")
    }
    return {
        "tokens": tokens,
        "wall_s": wall,
        "tokens_per_s": tokens / wall,
        "prefill_tokens": float(sum(prefill_tokens.values())),
    }


#: (active slots, max_seq) cells for the paged-vs-dense sweep — both
#: axes grow together so the dense path's reservation tax compounds
PAGED_SWEEP_CELLS = ((2, 1024), (3, 2048), (4, 4096))


def _paged_cell(arch: str, *, kv_mode: str, slots: int, max_seq: int,
                requests: int, prompt_len: int, new_tokens: int) -> float:
    """Tokens/s for one (slots, max_seq) cell in one kv_mode.

    The workload is deliberately *short-lived churn*: live sequences
    never exceed a couple of KV pages, so every byte of the dense mode's
    (B, max_seq) reservation — the padded prefill, the full-width
    attention, the full-width cache scatter — is pure overhead that the
    paged mode does not pay.  The page pool is sized to the live-token
    working set (4x headroom), NOT to max_seq — sizing the pool to the
    memory actually available is how paged KV deploys, and it is why the
    paged columns stay flat while the dense columns degrade.
    """
    page = ServerConfig.tokens_per_page
    pool = 4 * slots * (-(-(prompt_len + new_tokens + 1) // page) + 1)
    engine, cfg = _build_engine(
        arch, max_batch=slots, max_seq=max_seq, incremental=True,
        kv_mode=kv_mode, kv_pool_pages=pool,
    )
    assert engine.kv_mode == kv_mode
    # warmup: same request shape as the timed run, so every jit variant
    # (prefill width, decode table bucket) compiles outside the window
    for r in _requests(slots, prompt_len, new_tokens, 0, 0, cfg.vocab_size):
        r.request_id += 10_000
        engine.submit(r)
    engine.drain()

    reqs = _requests(requests, prompt_len, new_tokens, 0, 0, cfg.vocab_size)
    t0 = time.perf_counter()
    for r in reqs:
        engine.submit(r)
    engine.drain()
    wall = time.perf_counter() - t0
    assert all(r.error is None for r in reqs)
    assert engine.kv.total_runs() == 0
    assert engine.kv.pages_allocated == engine.kv.pages_freed
    return sum(len(r.tokens) for r in reqs) / wall


def run_paged_sweep(arch: str, *, prompt_len: int = 8,
                    new_tokens: int = 6) -> List[Dict[str, float]]:
    """A/B ``kv_mode`` over growing (slots, max_seq) cells.

    Returns one row per cell with both throughputs and the ratio; the
    caller asserts the ratio > 1 at the largest cell and that the gap
    grows along the sweep.
    """
    rows = []
    for slots, max_seq in PAGED_SWEEP_CELLS:
        cell = dict(slots=slots, max_seq=max_seq, requests=3 * slots,
                    prompt_len=prompt_len, new_tokens=new_tokens)
        dense = _paged_cell(arch, kv_mode="dense", **cell)
        paged = _paged_cell(arch, kv_mode="paged", **cell)
        rows.append({
            "slots": slots,
            "max_seq": max_seq,
            "dense_tokens_per_s": dense,
            "paged_tokens_per_s": paged,
            "speedup_x": paged / dense,
        })
    return rows


def run_chunk_interference(arch: str, *, long_prompt: int = 1024,
                           chunk: int = 32,
                           interactive_tokens: int = 48) -> Dict[str, float]:
    """Long-prompt admission interference on a live decode stream.

    One interactive request is mid-decode when a ``long_prompt``-token
    request arrives.  With monolithic prefill the admission tick runs
    the whole prompt before the live slot decodes again — a stall the
    interactive stream feels as one giant inter-token gap.  With a
    per-step budget (``prefill_chunk_tokens=chunk``) the prompt trickles
    in ``chunk`` rows per tick and the live slot decodes on every one
    of them, so the worst gap collapses to one-chunk-plus-one-decode.

    Measures wall-clock inter-token gaps on the interactive stream while
    the long prompt is in flight; the headline is the p99 ratio
    (monolithic over chunked), hard-floored at >= 3x.
    """
    page = ServerConfig.tokens_per_page
    pool = 4 * (-(-(long_prompt + interactive_tokens + 16) // page) + 2)

    def _one_pass(engine, cfg, rid: int) -> float:
        """One interference schedule; p99 inter-token gap on the
        interactive stream while the long prompt is in flight."""
        rng = np.random.default_rng(3)
        mk = lambda n, new, r: Request(  # noqa: E731
            prompt=rng.integers(0, cfg.vocab_size, (n,)).astype(np.int32),
            max_new_tokens=new, request_id=r,
        )
        inter = mk(8, interactive_tokens, rid)
        engine.submit(inter)
        while len(inter.tokens) < 4:       # settle into steady decode
            engine.step()
        engine.submit(mk(long_prompt, 2, rid + 1))
        gaps = []
        last = time.perf_counter()
        while not inter.done:
            engine.step()
            now = time.perf_counter()
            gaps.append(now - last)
            last = now
        engine.drain()
        assert inter.error is None
        assert engine.kv.total_runs() == 0
        return float(np.percentile(np.asarray(gaps), 99))

    def _measure(budget: int) -> float:
        engine, cfg = _build_engine(
            arch, max_batch=2, max_seq=long_prompt + 64, incremental=True,
            kv_mode="paged", kv_pool_pages=pool,
            prefill_chunk_tokens=budget,
        )
        # the warmup pass IS the timed schedule — identical admission
        # order, so every jit variant (prefill/chunk widths at their
        # exact positions, decode table buckets) compiles before the
        # timed pass
        _one_pass(engine, cfg, 10_000)
        return _one_pass(engine, cfg, 1)

    mono_p99 = _measure(0)
    chunk_p99 = _measure(chunk)
    reduction = mono_p99 / chunk_p99
    # the tentpole's acceptance gate: budgeted prefill must shrink the
    # interactive stream's worst stall by at least 3x.  Wall-clock, but
    # the two runs share a process and the stall being measured is a
    # ~long_prompt/chunk compute ratio, so 3x holds with wide margin
    assert reduction >= 3.0, (
        f"chunked prefill only cut the p99 inter-token stall "
        f"{reduction:.2f}x (mono {mono_p99 * 1e3:.1f}ms vs "
        f"chunked {chunk_p99 * 1e3:.1f}ms)"
    )
    return {
        "long_prompt": long_prompt,
        "chunk": chunk,
        "mono_intertoken_p99_ms": mono_p99 * 1e3,
        "chunk_intertoken_p99_ms": chunk_p99 * 1e3,
        "chunk_stall_reduction_x": reduction,
    }


def _shard_cell(arch: str, *, mesh_devices: int, slots: int = 2,
                max_seq: int = 48, requests: int = 6, prompt_len: int = 8,
                new_tokens: int = 6) -> float:
    """Tokens/s for one tensor-parallel cell (``mesh_devices=0`` = no mesh).

    Uses a TP-capable head layout (4 query heads over 4 KV heads) so the
    mesh sizes 1/2/4 all divide the head axes — the stock reduced config
    has a single KV head and would fall back to the unsharded path.
    """
    cfg = dataclasses.replace(
        get_reduced(arch), num_heads=4, num_kv_heads=4, head_dim=16,
    )
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    page = ServerConfig.tokens_per_page
    pool = 4 * slots * (-(-(prompt_len + new_tokens + 1) // page) + 1)
    engine = ServingEngine(
        model, params,
        ServerConfig(max_batch=slots, max_seq=max_seq, incremental=True,
                     kv_mode="paged", kv_pool_pages=pool),
        mesh=make_serving_mesh(mesh_devices) if mesh_devices else None,
    )
    expect_shards = mesh_devices if mesh_devices else 1
    assert engine.serving_stats()["tp_shards"] == expect_shards
    for r in _requests(slots, prompt_len, new_tokens, 0, 0, cfg.vocab_size):
        r.request_id += 10_000
        engine.submit(r)
    engine.drain()

    reqs = _requests(requests, prompt_len, new_tokens, 0, 0, cfg.vocab_size)
    t0 = time.perf_counter()
    for r in reqs:
        engine.submit(r)
    engine.drain()
    wall = time.perf_counter() - t0
    assert all(r.error is None for r in reqs)
    assert engine.kv.pages_allocated == engine.kv.pages_freed
    return sum(len(r.tokens) for r in reqs) / wall


def run_shard_sweep(arch: str) -> Dict[str, object]:
    """Tensor-parallel paged decode over mesh sizes 1/2/4.

    The 1-device mesh row must land within noise of the no-mesh baseline
    (shard_map over one device is the same computation, so a real gap
    means the TP plumbing itself costs throughput).  ``shard_speedup_x``
    is largest-mesh over 1-device-mesh tokens/s — on a *simulated* CPU
    mesh the shards timeshare one physical core, so this is a plumbing-
    overhead measurement (expected near or below 1x), not a scaling
    claim; on real multi-chip hardware the same sweep measures scaling.
    """
    base = _shard_cell(arch, mesh_devices=0)
    rows = []
    for n in (1, 2, 4):
        if n > len(jax.devices()):
            continue
        rows.append({
            "mesh_devices": n,
            "tokens_per_s": _shard_cell(arch, mesh_devices=n),
        })
    ratio1 = rows[0]["tokens_per_s"] / base
    assert 1 / 3 <= ratio1 <= 3, (
        f"1-device mesh row diverged from the no-mesh baseline: "
        f"{ratio1:.2f}x"
    )
    return {
        "no_mesh_tokens_per_s": base,
        "rows": rows,
        "shard_speedup_x": rows[-1]["tokens_per_s"] / rows[0]["tokens_per_s"],
    }


def run_sim_determinism(arch: str, seed: int = 7) -> str:
    """Engine trace under SimExecutor must be a pure function of the seed."""
    from repro.core import SimExecutor

    def once():
        engine, cfg = _build_engine(
            arch, max_batch=2, max_seq=48, incremental=True,
            executor=SimExecutor(seed=seed),
        )
        engine.cfg.step_time_s = 0.01
        for r in _requests(6, 8, 3, 3, 6, cfg.vocab_size):
            engine.submit(r)
        engine.drain()
        return hashlib.sha256(engine.trace_text().encode()).hexdigest()

    digests = {once() for _ in range(3)}
    assert len(digests) == 1, f"engine traces diverged: {digests}"
    return next(iter(digests))


def main(
    arch: str = "qwen2.5-32b",
    requests: int = 18,
    prompt_len: int = 32,
    new_tokens: int = 4,
    long_every: int = 6,
    long_tokens: int = 32,
    max_batch: int = 4,
    max_seq: int = 96,
    json_out: Optional[str] = None,
) -> Dict[str, float]:
    common = dict(
        requests=requests, prompt_len=prompt_len, new_tokens=new_tokens,
        long_every=long_every, long_tokens=long_tokens,
        max_batch=max_batch, max_seq=max_seq,
    )
    rebatch = run_mode(arch, incremental=False, **common)
    incremental = run_mode(arch, incremental=True, **common)
    speedup = incremental["tokens_per_s"] / rebatch["tokens_per_s"]
    # the acceptance floor lives here (hard assert) rather than in the
    # trend check: the ratio's absolute value swings with compile-time
    # weather (~16-42x), but a collapse toward rebatching-order cost is
    # exactly what this bench exists to catch
    assert speedup >= 2.0, (
        f"incremental engine only {speedup:.2f}x over rebatching"
    )
    prefill_saved = (
        rebatch["prefill_tokens"] / max(incremental["prefill_tokens"], 1.0)
    )

    sweep = run_paged_sweep(arch)
    paged_speedup = sweep[-1]["speedup_x"]
    # the tentpole's acceptance gate: paged must beat dense, and the gap
    # must widen as the reservation (slots x max_seq) grows — if paging
    # overhead ever swamps the reservation tax, this is where it shows
    assert paged_speedup > 1.0, (
        f"paged decode lost to dense at the largest cell: "
        f"{paged_speedup:.2f}x"
    )
    assert sweep[-1]["speedup_x"] > sweep[0]["speedup_x"], (
        "paged-vs-dense gap did not grow along the sweep: "
        + ", ".join(f"{r['speedup_x']:.2f}x" for r in sweep)
    )

    interference = run_chunk_interference(arch)

    shard = run_shard_sweep(arch)

    digest = run_sim_determinism(arch)

    print("# serve_bench")
    print(f"  arch={arch} requests={requests} batch={max_batch} "
          f"prompt={prompt_len} new={new_tokens} "
          f"long=1/{long_every}@{long_tokens}tok")
    print(f"  rebatching baseline : {rebatch['tokens_per_s']:8.1f} tok/s "
          f"({rebatch['prefill_tokens']:.0f} prefill tokens)")
    print(f"  incremental engine  : {incremental['tokens_per_s']:8.1f} tok/s "
          f"({incremental['prefill_tokens']:.0f} prefill tokens)")
    print(f"  speedup             : {speedup:.1f}x tokens/s, "
          f"{prefill_saved:.1f}x less prefill work")
    print("  paged-vs-dense sweep (short-lived churn):")
    for row in sweep:
        print(f"    slots={row['slots']} max_seq={row['max_seq']:5d} : "
              f"dense {row['dense_tokens_per_s']:8.1f} tok/s, "
              f"paged {row['paged_tokens_per_s']:8.1f} tok/s "
              f"-> {row['speedup_x']:.2f}x")
    print(f"  paged speedup       : {paged_speedup:.2f}x at the largest "
          f"cell (gap grows along the sweep)")
    print(f"  long-prompt interference ({interference['long_prompt']}-token "
          f"admit into a live decode):")
    print(f"    monolithic prefill: p99 inter-token gap "
          f"{interference['mono_intertoken_p99_ms']:8.1f} ms")
    print(f"    chunked (budget={interference['chunk']:3d}): p99 gap "
          f"{interference['chunk_intertoken_p99_ms']:8.1f} ms")
    print(f"  stall reduction     : "
          f"{interference['chunk_stall_reduction_x']:.1f}x (target:>=3x)")
    print("  tensor-parallel shard sweep (simulated mesh):")
    print(f"    no mesh           : "
          f"{shard['no_mesh_tokens_per_s']:8.1f} tok/s")
    for row in shard["rows"]:
        print(f"    mesh={row['mesh_devices']}            : "
              f"{row['tokens_per_s']:8.1f} tok/s")
    print(f"  shard speedup       : {shard['shard_speedup_x']:.2f}x "
          f"(mesh-{shard['rows'][-1]['mesh_devices']} vs mesh-1; "
          f"simulated shards timeshare one core)")
    print(f"  sim determinism     : 3 runs -> trace sha256 "
          f"{digest[:16]}... identical")

    result = {
        "arch": arch,
        "requests": requests,
        "max_batch": max_batch,
        "rebatch_tokens_per_s": rebatch["tokens_per_s"],
        "incremental_tokens_per_s": incremental["tokens_per_s"],
        "incremental_speedup_x": speedup,
        "rebatch_prefill_tokens": rebatch["prefill_tokens"],
        "incremental_prefill_tokens": incremental["prefill_tokens"],
        "prefill_reduction_x": prefill_saved,
        "paged_speedup_x": paged_speedup,
        "paged_sweep": sweep,
        "chunk_stall_reduction_x": interference["chunk_stall_reduction_x"],
        "mono_intertoken_p99_ms": interference["mono_intertoken_p99_ms"],
        "chunk_intertoken_p99_ms": interference["chunk_intertoken_p99_ms"],
        "chunk_interference": interference,
        "shard_speedup_x": shard["shard_speedup_x"],
        "shard_sweep": shard,
        "sim_trace_sha256": digest,
    }
    if json_out:
        with open(json_out, "w") as fh:
            json.dump(result, fh, indent=2, sort_keys=True)
        print(f"  wrote {json_out}")
    return result


if __name__ == "__main__":
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--arch", default="qwen2.5-32b")
    ap.add_argument("--requests", type=int, default=18)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--new-tokens", type=int, default=4)
    ap.add_argument("--long-every", type=int, default=6)
    ap.add_argument("--long-tokens", type=int, default=32)
    ap.add_argument("--max-batch", type=int, default=4)
    ap.add_argument("--max-seq", type=int, default=96)
    ap.add_argument("--json-out", default=None)
    a = ap.parse_args()
    main(arch=a.arch, requests=a.requests, prompt_len=a.prompt_len,
         new_tokens=a.new_tokens, long_every=a.long_every,
         long_tokens=a.long_tokens, max_batch=a.max_batch,
         max_seq=a.max_seq, json_out=a.json_out)
