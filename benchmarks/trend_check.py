"""Bench trend check — fail CI on large perf regressions.

The bench-smoke job writes ``BENCH_*.json`` artifacts every run; until
now nothing diffed them, so a regression only showed up when a human
compared artifacts by hand.  This script compares the current artifacts
against the previous run's and **fails (exit 1) on a > ``--tolerance``
regression** (default 30%) of any tracked metric:

* ``BENCH_pool.json`` ``warm_checkout_p50_us`` (lower is better),
* ``BENCH_admission.json`` ``warm_speedup_x`` (higher is better),
* ``BENCH_scheduler.json`` ``speedup_x`` (higher is better),
* ``BENCH_scheduler.json`` ``steal_speedup_x`` (higher is better).

Missing baselines are *skipped*, not failed — the first run of a branch,
a renamed artifact, or a new metric must not break CI.  Locally,
``make bench-trend`` runs the smoke benches and diffs against
``.bench-baseline/`` (seeding it on first use via ``--update-baseline``).
"""

from __future__ import annotations

import argparse
import json
import os
import shutil
import sys
from typing import Dict, List, Mapping, Optional, Tuple

#: (artifact file, metric key, direction, noise scale).  direction says
#: which way is good; the base --tolerance is multiplied by the noise
#: scale per metric.  warm_checkout_p50_us is a ~5us absolute timing:
#: even best-of-5 it carries a ~2x machine-state noise floor on shared
#: runners, and its gate exists to catch the order-of-magnitude jump of
#: the warm path going cold (5-30x), so it runs at twice the tolerance.
#: The speedup ratios are same-process relative measures and hold 30%.
#: serve_bench's incremental_speedup_x mixes FLOP savings with the
#: rebatching baseline's per-wave recompiles, so its run-to-run spread
#: (~16-42x) is wider than any sane relative tolerance — the >=2x
#: floor is asserted inside serve_bench itself instead.  The trend row
#: tracks prefill_reduction_x, a pure work ratio that is stable.
#: paged_speedup_x is a same-process wall-clock ratio but swings ~2x
#: with machine load (2.7-4.4x observed), so it runs at twice the
#: tolerance; its hard gates (>1x at the largest cell, gap growing
#: along the sweep) are asserted inside serve_bench every run.
TRACKED = (
    ("BENCH_pool.json", "warm_checkout_p50_us", "lower", 2.0),
    ("BENCH_admission.json", "warm_speedup_x", "higher", 1.0),
    ("BENCH_scheduler.json", "speedup_x", "higher", 1.0),
    ("BENCH_scheduler.json", "steal_speedup_x", "higher", 1.0),
    ("BENCH_serve.json", "prefill_reduction_x", "higher", 1.0),
    ("BENCH_serve.json", "paged_speedup_x", "higher", 2.0),
    # p99 inter-token stall, monolithic over chunked prefill, during a
    # long-prompt admit into a live decode.  The >=3x floor is hard-
    # asserted inside serve_bench; this row catches slow erosion of the
    # margin.  Wall-clock p99s on shared runners swing with machine
    # load, so it runs at twice the tolerance like paged_speedup_x
    ("BENCH_serve.json", "chunk_stall_reduction_x", "higher", 2.0),
    # a pure work ratio (prefilled tokens, not wall clock): deterministic
    # given the workload, so it holds the base tolerance.  Its >=2x floor
    # at 75% overlap is hard-asserted inside prefix_bench every run;
    # this row catches the slow drift (e.g. the radix lookup matching
    # ever-shorter prefixes) that a binary floor never would
    ("BENCH_prefix.json", "prefix_prefill_tokens_saved_x", "higher", 1.0),
    # shard_speedup_x is mesh-4 over mesh-1 TP decode on a *simulated*
    # CPU mesh: the four shards timeshare one physical core, so the
    # ratio measures shard_map plumbing overhead (near 1x), not scaling,
    # and collective-scheduling jitter swings it hard run to run.  The
    # generous scale still catches the failure this row exists for —
    # the sharded path collapsing (e.g. a psum falling onto the host
    # transfer path) to a small fraction of the unsharded throughput.
    ("BENCH_serve.json", "shard_speedup_x", "higher", 3.0),
    # decode p50 under mixed load, class-aware vs naive FIFO — a pure
    # virtual-clock scheduling ratio (no wall time anywhere), so it is
    # deterministic per workload and holds the base tolerance.  Its >1x
    # floor is hard-asserted inside orchestrator_bench every run; this
    # row catches the slow erosion of the protection margin
    ("BENCH_orchestrator.json", "decode_p50_protection_x", "higher", 1.0),
)


def compare_metric(
    old: Mapping, new: Mapping, key: str, direction: str, tolerance: float
) -> Optional[str]:
    """A human-readable regression line, or None if within tolerance."""
    if key not in old or key not in new:
        return None
    old_v, new_v = float(old[key]), float(new[key])
    if old_v <= 0:
        return None                       # degenerate baseline: no signal
    if direction == "lower":
        regressed = new_v > old_v * (1.0 + tolerance)
        change = new_v / old_v - 1.0
    else:
        regressed = new_v < old_v * (1.0 - tolerance)
        change = 1.0 - new_v / old_v
    if not regressed:
        return None
    return (
        f"{key}: {old_v:.3f} -> {new_v:.3f} "
        f"({change:+.0%} worse; direction={direction}, "
        f"tolerance={tolerance:.0%})"
    )


def _load(path: str) -> Optional[Dict]:
    if not os.path.isfile(path):
        return None
    try:
        with open(path) as fh:
            return json.load(fh)
    except (OSError, ValueError):
        return None


def run(
    old_dir: str, new_dir: str, tolerance: float = 0.30
) -> Tuple[List[str], List[str], List[str]]:
    """Returns (regressions, checked, skipped) description lines."""
    regressions: List[str] = []
    checked: List[str] = []
    skipped: List[str] = []
    for fname, key, direction, noise_scale in TRACKED:
        new = _load(os.path.join(new_dir, fname))
        if new is None:
            skipped.append(f"{fname}: no current artifact")
            continue
        old = _load(os.path.join(old_dir, fname))
        if old is None:
            skipped.append(f"{fname}: no baseline (first run?)")
            continue
        if key not in old or key not in new:
            skipped.append(f"{fname}: metric {key!r} absent")
            continue
        line = compare_metric(
            old, new, key, direction, tolerance * noise_scale
        )
        if line is not None:
            regressions.append(f"{fname} {line}")
        else:
            checked.append(
                f"{fname} {key}: {float(old[key]):.3f} -> "
                f"{float(new[key]):.3f} OK"
            )
    return regressions, checked, skipped


def update_baseline(old_dir: str, new_dir: str) -> List[str]:
    """Copy current artifacts over the baseline; returns copied names."""
    os.makedirs(old_dir, exist_ok=True)
    copied = []
    for fname, _, _, _ in TRACKED:
        src = os.path.join(new_dir, fname)
        if os.path.isfile(src):
            shutil.copyfile(src, os.path.join(old_dir, fname))
            copied.append(fname)
    return copied


def main(argv: Optional[List[str]] = None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--old-dir", required=True,
                    help="directory holding the previous BENCH_*.json")
    ap.add_argument("--new-dir", default=".",
                    help="directory holding the current BENCH_*.json")
    ap.add_argument("--tolerance", type=float, default=0.30,
                    help="relative regression that fails (0.30 = 30%%)")
    ap.add_argument("--update-baseline", action="store_true",
                    help="on success, copy current artifacts into "
                         "--old-dir for the next comparison")
    args = ap.parse_args(argv)

    regressions, checked, skipped = run(
        args.old_dir, args.new_dir, tolerance=args.tolerance
    )
    print("# trend_check")
    for line in checked:
        print(f"  ok       {line}")
    for line in skipped:
        print(f"  skipped  {line}")
    for line in regressions:
        print(f"  REGRESSED {line}")
    if regressions:
        print(f"trend_check: {len(regressions)} regression(s) beyond "
              f"{args.tolerance:.0%}")
        return 1
    if args.update_baseline:
        for fname in update_baseline(args.old_dir, args.new_dir):
            print(f"  baseline {fname} updated in {args.old_dir}")
    return 0


if __name__ == "__main__":
    sys.exit(main())
