"""Concurrent vs serial task throughput on a mixed-latency workload.

The §V.A scheduler gained N worker threads over per-tenant fair queues.
This bench runs the same workload — three tenants submitting a mix of
fast (2ms), medium (10ms) and slow (30ms) sandboxed tasks — through

* **serial**: the seed's single-threaded ``run_pending()`` drain, and
* **concurrent**: ``workers=N`` draining the same queues in parallel
  (task bodies release the GIL in their I/O region, as real UDF
  post-processors do),

and reports tasks/second for each.  Target: **>= 2x** with 4 workers.

It then measures **work stealing** on a skewed-tenant load: every worker
is pinned (affinity) to its own tenant, but only one tenant — ``hot``,
below its in-flight cap — has any work.  Without stealing the other
workers idle and throughput collapses to one worker's; with stealing
they drain the hot backlog.  Target: **>= 2x** steal speedup with 4
workers.

Finally it proves the determinism story: the same workload under a
``SimExecutor`` with one seed, run three times, must produce
**byte-identical scheduling traces** (and identical TaskRecord
histories).  ``--json-out`` writes a ``BENCH_scheduler.json`` artifact
for the CI trend check.
"""

from __future__ import annotations

import argparse
import hashlib
import json
import time
from typing import Dict, List, Optional

from repro.core import (
    ServerlessScheduler,
    SimExecutor,
    TaskSpec,
    TaskState,
    TenantQuota,
)

TENANTS = ("alpha", "beta", "gamma")
# (share of tasks, sleep seconds): the paper's mixed Serverless Tasks load
LATENCY_MIX = ((0.5, 0.002), (0.3, 0.010), (0.2, 0.030))


def _make_task_fns(sleeper):
    """One closure per latency class, so admission verifies each once."""
    fns = []
    for _, delay in LATENCY_MIX:
        def task(x, _delay=delay):
            sleeper(_delay)               # I/O region: releases the GIL
            return x
        fns.append(task)
    return fns


# repeating pattern realizing the 50/30/20 mix without RNG, interleaved
# so latency classes never cluster into bursts
_PATTERN = (0, 1, 0, 2, 0, 1, 0, 1, 2, 0)


def _workload(n_tasks: int) -> List[int]:
    """Deterministic latency-class index per task (no RNG needed)."""
    return [_PATTERN[i % len(_PATTERN)] for i in range(n_tasks)]


def _submit_all(sched: ServerlessScheduler, fns, classes) -> List[int]:
    import numpy as np

    x = np.ones(4, np.float32)
    ids = []
    for i, cls in enumerate(classes):
        ids.append(sched.submit(TaskSpec(
            TENANTS[i % len(TENANTS)], fns[cls], (x,),
            name=f"task{i}",
        )))
    return ids


def _quotas(workers: int) -> Dict[str, TenantQuota]:
    return {t: TenantQuota(max_tasks_in_flight=max(2, workers)) for t in TENANTS}


def run_real(n_tasks: int, workers: int) -> float:
    """Tasks/second on real threads (0 workers = serial drain)."""
    fns = _make_task_fns(time.sleep)
    sched = ServerlessScheduler(workers=workers, quotas=_quotas(workers or 1))
    ids = _submit_all(sched, fns, _workload(n_tasks))
    t0 = time.perf_counter()
    if workers > 0:
        sched.start()
        sched.drain(timeout=120)
    else:
        sched.run_pending()
    wall = time.perf_counter() - t0
    bad = [i for i in ids if sched.record(i).state is not TaskState.SUCCEEDED]
    assert not bad, f"tasks not succeeded: {bad}"
    if workers > 0:
        sched.shutdown()
    return n_tasks / wall


def run_skewed(n_tasks: int, workers: int, *, steal: bool) -> float:
    """Tasks/second on the skewed-tenant workload (real threads).

    ``workers`` tenants, one worker pinned to each; all ``n_tasks`` land
    on the first tenant (``hot``, cap = workers, i.e. unthrottled).  With
    ``steal=False`` only hot's home worker may serve them; with stealing
    the idle workers take over the backlog.
    """
    import numpy as np

    tenants = ["hot"] + [f"idle{i}" for i in range(1, workers)]
    affinity = {f"w{i}": [tenants[i]] for i in range(workers)}
    quotas = {t: TenantQuota(max_tasks_in_flight=workers) for t in tenants}
    sched = ServerlessScheduler(
        workers=workers, quotas=quotas, affinity=affinity, steal=steal,
    )

    def task(x):
        time.sleep(0.004)             # I/O region: releases the GIL
        return x

    x = np.ones(4, np.float32)
    ids = [sched.submit(TaskSpec("hot", task, (x,), name=f"skew{i}"))
           for i in range(n_tasks)]
    t0 = time.perf_counter()
    sched.start()
    sched.drain(timeout=120)
    wall = time.perf_counter() - t0
    bad = [i for i in ids if sched.record(i).state is not TaskState.SUCCEEDED]
    assert not bad, f"tasks not succeeded: {bad}"
    if steal:
        assert sched.steal_count > 0, "skewed run recorded no steals"
    else:
        assert sched.steal_count == 0
    sched.shutdown()
    return n_tasks / wall


def run_sim(n_tasks: int, workers: int, seed: int):
    """The same workload under the deterministic simulator."""
    sim = SimExecutor(seed=seed)
    fns = _make_task_fns(sim.sleep)
    sched = ServerlessScheduler(
        workers=workers, executor=sim, quotas=_quotas(workers)
    )
    ids = _submit_all(sched, fns, _workload(n_tasks))
    sched.start()
    sched.drain()
    trace = sched.trace_text()
    histories = tuple(sched.record(i).history() for i in ids)
    sched.shutdown()
    return trace, histories


def main(
    tasks: int = 60,
    workers: int = 4,
    seed: int = 7,
    json_out: Optional[str] = None,
) -> Dict[str, float]:
    serial_tps = run_real(tasks, workers=0)
    concurrent_tps = run_real(tasks, workers=workers)
    speedup = concurrent_tps / serial_tps

    # ---- skewed tenant: work stealing vs pinned-only dispatch ---------
    skew_tasks = max(20, tasks // 2)
    nosteal_tps = run_skewed(skew_tasks, workers, steal=False)
    steal_tps = run_skewed(skew_tasks, workers, steal=True)
    steal_speedup = steal_tps / nosteal_tps

    # ---- determinism: same seed => byte-identical scheduling trace ----
    runs = [run_sim(tasks, workers, seed) for _ in range(3)]
    digests = [
        hashlib.sha256(trace.encode()).hexdigest() for trace, _ in runs
    ]
    deterministic = (
        len(set(digests)) == 1
        and runs[0][1] == runs[1][1] == runs[2][1]
    )
    assert deterministic, f"sim traces diverged across runs: {digests}"

    print("# scheduler_bench")
    print(f"  tasks={tasks} workers={workers} mix="
          f"{'/'.join(f'{int(s*100)}%@{d*1e3:.0f}ms' for s, d in LATENCY_MIX)}")
    print(f"  serial drain        : {serial_tps:8.1f} tasks/s")
    print(f"  {workers} workers           : {concurrent_tps:8.1f} tasks/s "
          f"({speedup:.1f}x)")
    print(f"  skewed, no stealing : {nosteal_tps:8.1f} tasks/s "
          f"(1 of {workers} workers eligible)")
    print(f"  skewed, stealing    : {steal_tps:8.1f} tasks/s "
          f"({steal_speedup:.1f}x)")
    print(f"  sim determinism     : 3 runs seed={seed} -> "
          f"trace sha256 {digests[0][:16]}... identical={deterministic}")

    result = {
        "tasks": tasks,
        "workers": workers,
        "serial_tasks_per_s": serial_tps,
        "concurrent_tasks_per_s": concurrent_tps,
        "speedup_x": speedup,
        "skewed_nosteal_tasks_per_s": nosteal_tps,
        "skewed_steal_tasks_per_s": steal_tps,
        "steal_speedup_x": steal_speedup,
        "sim_trace_sha256": digests[0],
        "sim_deterministic": deterministic,
    }
    if json_out:
        with open(json_out, "w") as fh:
            json.dump(result, fh, indent=2, sort_keys=True)
        print(f"  wrote {json_out}")
    return result


if __name__ == "__main__":
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--tasks", type=int, default=60)
    ap.add_argument("--workers", type=int, default=4)
    ap.add_argument("--seed", type=int, default=7)
    ap.add_argument("--json-out", default=None)
    a = ap.parse_args()
    main(tasks=a.tasks, workers=a.workers, seed=a.seed, json_out=a.json_out)
