"""Paper §IV.A benchmark: list-append 2-D array growth, VMA counts.

Reproduces the paper's synthetic workload — "repeatedly appending new
lists into an existing list to build a two-dimensional array": each append
allocates a sublist arena (one granule, placed top-down → descending
addresses); the outer pointer array reallocs on capacity doubling.  We
count host VMAs for:

* **native** — a Linux-like allocator that extends a single heap VMA
  (plus ~128 baseline mappings for libraries etc.),
* **legacy** — gVisor-like MM with the offset-direction bug,
* **modern** — the paper's fix (direction-aligned offsets + hint
  preservation across merges),
* **modern+churn** — the fix under allocator churn (an unrelated arena
  faults every ``churn`` appends, breaking coalescing chains — the effect
  that bounds the paper's measured 182x).

Paper claims to check: legacy > 500x native (and past the 65,530
``vm.max_map_count`` crash line); the fix reduces VMA entries by ~182x.
"""

from __future__ import annotations

import time
from dataclasses import dataclass
from typing import Dict

from repro.core.mm import MemoryManager, MMConfig
from repro.core.vma import MAX_MAP_COUNT

G = 64 * 1024
BASELINE_NATIVE_MAPPINGS = 128   # libraries, stacks, … (constant offset)


def list_append_workload(mm: MemoryManager, appends: int,
                         churn: int = 0) -> None:
    """Append ``appends`` sublists; outer array reallocs on doubling."""
    churn_arena = mm.mmap(G * (appends // max(churn, 1) + 2)) if churn else None
    churn_faults = 0
    capacity = 1
    for i in range(1, appends + 1):
        sub = mm.mmap(G)                       # sublist arena
        mm.touch(sub.start, G)
        if i >= capacity:                       # outer pointer-array realloc
            capacity *= 2
            nbytes = max(capacity * 8, G)
            outer = mm.mmap(nbytes)
            mm.touch(outer.start, nbytes)
        if churn and i % churn == 0:            # unrelated allocator churn
            mm.touch(churn_arena.start + churn_faults * G, G)
            churn_faults += 1


@dataclass
class VmaResult:
    variant: str
    host_vmas: int
    sentry_vmas: int
    crash: bool
    wall_s: float


def run(appends: int = 70_000, churn: int = 200) -> Dict[str, VmaResult]:
    results: Dict[str, VmaResult] = {}

    # native: one heap VMA regardless of appends
    results["native"] = VmaResult(
        "native", BASELINE_NATIVE_MAPPINGS + 2, 2, False, 0.0
    )

    variants = {
        "legacy": (MMConfig.legacy(), 0),
        "modern": (MMConfig.modern(), 0),
        "modern+churn": (MMConfig.modern(), churn),
    }
    for name, (cfg, ch) in variants.items():
        mm = MemoryManager(cfg)
        t0 = time.perf_counter()
        list_append_workload(mm, appends, churn=ch)
        wall = time.perf_counter() - t0
        n = mm.host_vma_count() + BASELINE_NATIVE_MAPPINGS
        results[name] = VmaResult(
            name, n, len(mm.vmas), n > MAX_MAP_COUNT, wall
        )
    return results


def main(appends: int = 70_000) -> Dict[str, float]:
    res = run(appends)
    native = res["native"].host_vmas
    legacy = res["legacy"].host_vmas
    modern = res["modern"].host_vmas
    churn = res["modern+churn"].host_vmas
    print(f"# vma_bench: {appends} appends, granule 64KiB")
    for r in res.values():
        crash = "  ** exceeds vm.max_map_count → sandbox crash **" if r.crash else ""
        print(f"  {r.variant:14s} host_vmas={r.host_vmas:7d} "
              f"(sentry={r.sentry_vmas})  [{r.wall_s:.2f}s]{crash}")
    print(f"  legacy/native blow-up : {legacy / native:8.1f}x  (paper: >500x)")
    print(f"  fix reduction (clean) : {legacy / modern:8.1f}x  (paper: 182x)")
    print(f"  fix reduction (churn) : {legacy / churn:8.1f}x")
    return {
        "blowup_x": legacy / native,
        "reduction_clean_x": legacy / modern,
        "reduction_churn_x": legacy / churn,
        "legacy_crash": float(res["legacy"].crash),
    }


if __name__ == "__main__":
    main()
