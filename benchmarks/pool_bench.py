"""Warm-pool checkout under async refill vs cold-build checkout.

PR 1 hid sandbox construction behind a warm pool but still built cold on
the checkout path whenever the free list ran dry.  This bench measures the
scenario async refill fixes: every request *consumes* its sandbox (checkin
with ``discard=True``, as after a policy violation or a single-use task),
so without a refiller each checkout pays a cold build.

* **cold**: no watermark, no refiller — every checkout builds.
* **warm**: ``refill_watermark > 0`` with the pump running between
  requests (explicit ``tick()`` by default, ``--threaded`` for the daemon
  refiller) — checkouts pop a pre-built sandbox; the cold-checkout
  counter (``seepp_pool_cold_checkout_total``) must stay 0 in steady
  state.

Prints p50/p95 per mode and the warm-vs-cold speedup (target >= 5x); with
``--json-out`` also writes a ``BENCH_pool.json`` artifact for CI.
"""

from __future__ import annotations

import argparse
import json
import time
from typing import Dict, List, Optional

from repro.core import SandboxPool


def _percentile(samples: List[float], q: float) -> float:
    ordered = sorted(samples)
    idx = min(len(ordered) - 1, int(q * len(ordered)))
    return ordered[idx]


def _drive(
    pool: SandboxPool,
    requests: int,
    *,
    tick: bool,
    tenant: str = "bench",
) -> List[float]:
    """Checkout/consume ``requests`` times, returning checkout latencies."""
    times: List[float] = []
    for _ in range(requests):
        t0 = time.perf_counter()
        sb = pool.checkout(tenant)
        times.append(time.perf_counter() - t0)
        pool.checkin(sb, discard=True)       # consumed: force a rebuild
        if tick:
            pool.tick()
        elif pool.refiller_running:
            # think time between requests — the window the background
            # refiller hides the build in; wait on the *clamped* target
            # (a watermark above max_idle_per_tenant is never reached)
            while pool.idle_count(tenant) < pool.refill_target(tenant):
                time.sleep(1e-4)
    return times


def main(
    requests: int = 200,
    watermark: int = 4,
    threaded: bool = False,
    json_out: Optional[str] = None,
    repeats: int = 3,
) -> Dict[str, float]:
    # best-of-N percentiles (timeit-style): scheduler jitter on shared
    # CI runners only ever makes a run *slower*, so the minimum across
    # repeats is the reproducible statistic the trend check diffs
    cold_p50 = cold_p95 = warm_p50 = warm_p95 = float("inf")
    warm_pool = None
    for _ in range(max(1, repeats)):
        # ---- cold: every checkout builds on the hot path -------------
        cold_pool = SandboxPool()
        cold = _drive(cold_pool, requests, tick=False)
        assert cold_pool.stats.misses == requests

        # ---- warm: async refill keeps the free list above watermark --
        warm_pool = SandboxPool(refill_watermark=watermark)
        warm_pool.set_watermark("bench", watermark)
        warm_pool.tick()                     # pre-warm to the watermark
        if threaded:
            warm_pool.start_refiller(interval_s=0.001)
        try:
            warm = _drive(warm_pool, requests, tick=not threaded)
        finally:
            warm_pool.stop_refiller()

        cold_p50 = min(cold_p50, _percentile(cold, 0.5))
        cold_p95 = min(cold_p95, _percentile(cold, 0.95))
        warm_p50 = min(warm_p50, _percentile(warm, 0.5))
        warm_p95 = min(warm_p95, _percentile(warm, 0.95))
    speedup = cold_p50 / warm_p50 if warm_p50 > 0 else float("inf")

    print("# pool_bench")
    print(f"  requests={requests} watermark={watermark} "
          f"pump={'thread' if threaded else 'tick'}")
    print(f"  cold-build checkout : p50 {cold_p50*1e6:9.1f} us   "
          f"p95 {cold_p95*1e6:9.1f} us")
    print(f"  warm-pool checkout  : p50 {warm_p50*1e6:9.1f} us   "
          f"p95 {warm_p95*1e6:9.1f} us   ({speedup:.0f}x faster)")
    print(f"  warm cold_checkouts : {warm_pool.stats.misses} "
          f"(steady-state target: 0)   refills: {warm_pool.stats.refills}")

    result = {
        "requests": requests,
        "watermark": watermark,
        "cold_checkout_p50_us": cold_p50 * 1e6,
        "cold_checkout_p95_us": cold_p95 * 1e6,
        "warm_checkout_p50_us": warm_p50 * 1e6,
        "warm_checkout_p95_us": warm_p95 * 1e6,
        "warm_speedup_x": speedup,
        "warm_cold_checkout_total": warm_pool.stats.misses,
        "warm_refill_total": warm_pool.stats.refills,
    }
    if json_out:
        with open(json_out, "w") as fh:
            json.dump(result, fh, indent=2, sort_keys=True)
        print(f"  wrote {json_out}")
    return result


if __name__ == "__main__":
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--requests", type=int, default=200)
    ap.add_argument("--watermark", type=int, default=4)
    ap.add_argument("--threaded", action="store_true",
                    help="drive the daemon refiller instead of tick()")
    ap.add_argument("--json-out", default=None, metavar="PATH",
                    help="write results as JSON (CI bench artifact)")
    ap.add_argument("--repeats", type=int, default=3,
                    help="best-of-N runs (noise floor for the trend check)")
    a = ap.parse_args()
    main(requests=a.requests, watermark=a.watermark,
         threaded=a.threaded, json_out=a.json_out, repeats=a.repeats)
