"""Admission control plane: cold vs warm admission + pool checkout cost.

The paper pays interception cost **once** at load time and hides sandbox
startup with pooling/pre-warming.  Two measurements:

* **cold vs warm admission**: first submission of a UDF traces + verifies
  (``jax.make_jaxpr`` + ``static_verify``); a repeat submission of the
  same program hits the jaxpr-fingerprint cache and skips both.  The
  ratio is the load-time cost the cache amortizes away (target ≥ 10x).
* **pool checkout**: drawing a warm sandbox from :class:`SandboxPool`
  vs constructing a cold :class:`Sandbox` per request.
"""

from __future__ import annotations

import argparse
import json
import time
from typing import Dict, Optional

import jax
import jax.numpy as jnp

from repro.core import (
    AdmissionController,
    ModernEmulationPolicy,
    Sandbox,
    SandboxPool,
)


def udf(x, w1, w2):
    h = jnp.tanh(x @ w1)
    h = h * jax.nn.sigmoid(h)
    return jnp.sum((h @ w2) ** 2)


def main(
    cold_iters: int = 20,
    warm_reps: int = 2000,
    pool_reps: int = 200,
    size: int = 256,
    json_out: Optional[str] = None,
) -> Dict[str, float]:
    x = jnp.ones((size, size))
    w1 = jnp.ones((size, size)) * 0.01
    w2 = jnp.ones((size, size // 2)) * 0.01
    args = (x, w1, w2)
    policy = ModernEmulationPolicy()

    # ---- cold vs warm admission --------------------------------------
    cold_times = []
    for _ in range(cold_iters):
        ctl = AdmissionController()          # fresh cache → cold path
        t0 = time.perf_counter()
        ctl.admit(udf, args, policy=policy)
        cold_times.append(time.perf_counter() - t0)
    t_cold = sorted(cold_times)[len(cold_times) // 2]

    ctl = AdmissionController()
    ctl.admit(udf, args, policy=policy)      # populate
    reps = warm_reps
    t0 = time.perf_counter()
    for _ in range(reps):
        ctl.admit(udf, args, policy=policy)
    t_warm = (time.perf_counter() - t0) / reps
    assert ctl.stats()["hits"] == reps

    speedup = t_cold / t_warm

    # ---- pool checkout vs cold sandbox construction ------------------
    reps = pool_reps
    t0 = time.perf_counter()
    for _ in range(reps):
        Sandbox(tenant="bench")
    t_cold_boot = (time.perf_counter() - t0) / reps

    pool = SandboxPool()
    pool.prewarm("bench", 1)
    t0 = time.perf_counter()
    for _ in range(reps):
        sb = pool.checkout("bench")
        pool.checkin(sb)
    t_checkout = (time.perf_counter() - t0) / reps
    assert pool.stats.hits == reps

    print("# admission_bench")
    print(f"  cold admission (trace+verify): {t_cold*1e6:9.1f} us/program")
    print(f"  warm admission (cache hit)   : {t_warm*1e6:9.1f} us/program "
          f"({speedup:.0f}x faster)")
    print(f"  cold sandbox construction    : {t_cold_boot*1e6:9.1f} us")
    print(f"  warm pool checkout+checkin   : {t_checkout*1e6:9.1f} us "
          f"({t_cold_boot/t_checkout:.0f}x faster)")
    result = {
        "cold_admission_us": t_cold * 1e6,
        "warm_admission_us": t_warm * 1e6,
        "warm_speedup_x": speedup,
        "pool_checkout_speedup_x": t_cold_boot / t_checkout,
    }
    if json_out:
        with open(json_out, "w") as fh:
            json.dump(result, fh, indent=2, sort_keys=True)
        print(f"  wrote {json_out}")
    return result


if __name__ == "__main__":
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--cold-iters", type=int, default=20)
    ap.add_argument("--warm-reps", type=int, default=2000)
    ap.add_argument("--pool-reps", type=int, default=200)
    ap.add_argument("--size", type=int, default=256,
                    help="matrix side for the benched UDF (tiny for CI)")
    ap.add_argument("--json-out", default=None, metavar="PATH",
                    help="write results as JSON (CI bench artifact)")
    a = ap.parse_args()
    main(cold_iters=a.cold_iters, warm_reps=a.warm_reps,
         pool_reps=a.pool_reps, size=a.size, json_out=a.json_out)
