"""§III.C claim: interception must be cheap (the Systrap story).

Three measurements on a representative UDF:

* **steady-state**: jit-compiled execution inside the sandbox vs direct —
  must be ~0% (interception happens at trace/verify time; the emitted XLA
  is identical),
* **admission**: one-time verify cost per policy (the legacy allowlist
  does more lookups per equation — its "filter table" overhead),
* **full emulation**: the eqn-by-eqn interpreter, the analogue of running
  under ptrace — slow, which is exactly why gVisor moved to Systrap and
  why the production path verifies-then-compiles.
"""

from __future__ import annotations

import time
from typing import Dict

import jax
import jax.numpy as jnp

from repro.core import (
    LegacyFilterPolicy,
    ModernEmulationPolicy,
    sandboxed,
    static_verify,
)


def udf(x, w1, w2):
    h = jnp.tanh(x @ w1)
    h = h * jax.nn.sigmoid(h)
    return jnp.sum((h @ w2) ** 2)


def _median_time(fn, reps=20):
    fn()  # warmup
    times = []
    for _ in range(reps):
        t0 = time.perf_counter()
        jax.block_until_ready(fn())
        times.append(time.perf_counter() - t0)
    return sorted(times)[len(times) // 2]


def main() -> Dict[str, float]:
    x = jnp.ones((512, 512))
    w1 = jnp.ones((512, 512)) * 0.01
    w2 = jnp.ones((512, 256)) * 0.01
    args = (x, w1, w2)

    direct = jax.jit(udf)
    t_direct = _median_time(lambda: direct(*args))

    verified = jax.jit(sandboxed(udf, ModernEmulationPolicy()))
    t_verified = _median_time(lambda: verified(*args))

    interp = sandboxed(udf, ModernEmulationPolicy(), mode="interpret")
    t_interp = _median_time(lambda: interp(*args), reps=5)

    closed = jax.make_jaxpr(udf)(*args)
    t_admit = {}
    for policy in (LegacyFilterPolicy().extended("custom_jvp_call",
                                                 "integer_pow"),
                   ModernEmulationPolicy()):
        t0 = time.perf_counter()
        for _ in range(200):
            static_verify(closed, policy)
        t_admit[policy.name] = (time.perf_counter() - t0) / 200

    steady_pct = (t_verified - t_direct) / t_direct * 100
    print("# sentry_overhead")
    print(f"  direct jit           : {t_direct*1e6:9.1f} us/call")
    print(f"  sandboxed (verify)   : {t_verified*1e6:9.1f} us/call "
          f"({steady_pct:+.1f}% steady-state)")
    print(f"  full emulation       : {t_interp*1e6:9.1f} us/call "
          f"({t_interp/t_direct:.0f}x — the 'ptrace mode'; production path "
          "verifies then compiles)")
    for name, t in t_admit.items():
        print(f"  admission [{name:13s}]: {t*1e6:9.1f} us/program")
    return {
        "steady_state_overhead_pct": steady_pct,
        "emulation_slowdown_x": t_interp / t_direct,
        **{f"admit_{k}": v for k, v in t_admit.items()},
    }


if __name__ == "__main__":
    main()
